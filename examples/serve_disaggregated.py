"""Prompt/token disaggregation (paper §4.2.1): the planner splits D machines
into a prompt pipeline and a token pipeline; the prompt KV cache streams
P→T through DéjàVuLib, and generated tokens match the colocated baseline
bit-for-bit.

    PYTHONPATH=src python examples/serve_disaggregated.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import costmodel as cm
from repro.core.planner import plan
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    cfg = dataclasses.replace(get_arch("gpt2-1.5b").reduced(), num_layers=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # the planner on the FULL-SCALE model shows the Eq.-5 split logic
    full = get_arch("opt-66b")
    wl = cm.WorkloadSpec(prompt_len=1000, new_tokens=220, microbatch=16)
    p = plan(full, wl, d=8)
    print(f"planner (OPT-66B, D=8): Dp={p.d_prompt} Dt={p.d_token} "
          f"m={p.m_overhead:.3f} I_c={p.inv_tp_colocated:.2f}s "
          f"I_dis={p.inv_tp_disagg:.2f}s speedup={p.speedup:.2f}x")

    rng = np.random.default_rng(1)
    def reqs():
        rng_ = np.random.default_rng(1)
        return [Request(rid=i, prompt=rng_.integers(0, cfg.vocab_size, 12)
                        .astype(np.int32), max_new=6) for i in range(4)]

    base = ServingEngine(cfg, model, params, 4, mode="colocated", microbatch=2)
    rb = base.run(reqs())
    dis = ServingEngine(cfg, model, params, 4, mode="disaggregated",
                        dp_split=(1, 3), microbatch=2)
    rd = dis.run(reqs())
    print("tokens identical to colocated:", rd.tokens == rb.tokens)
    print("P->T prompt-KV bytes over network:", dis.transfer_summary()["net"])


if __name__ == "__main__":
    main()
