"""End-to-end fault-tolerant training on the SmolLM family (reduced scale for
this CPU container; the same driver trains the full 360M config on a pod).

Demonstrates: synthetic data pipeline (host-sharded, step-addressable),
AdamW + remat + grad accumulation, atomic checkpointing, and crash-resume:
the script checkpoints every 25 steps, then simulates a crash at step 60 and
resumes bit-identically.

    PYTHONPATH=src python examples/train_smollm.py
"""
import dataclasses
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import build_model
from repro.training import (SyntheticDataPipeline, adamw_init, latest_step,
                            make_train_step, restore_checkpoint, save_checkpoint)
from repro.training.train import TrainConfig


def main():
    cfg = dataclasses.replace(get_arch("smollm-360m").reduced(),
                              num_layers=4, d_model=128, d_ff=512,
                              dtype="float32")
    model = build_model(cfg, remat=True)
    data = SyntheticDataPipeline(cfg.vocab_size, seq_len=64, global_batch=8,
                                 seed=0)
    step_fn = jax.jit(make_train_step(model, TrainConfig(lr=1e-3, grad_accum=2)))
    ckpt_dir = tempfile.mkdtemp(prefix="dejavu-train-")

    def train(until, params, opt, start):
        for step in range(start, until):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            params, opt, m = step_fn(params, opt, batch)
            if (step + 1) % 20 == 0:
                print(f"  step {step+1:3d} loss={float(m['loss']):.4f}")
            if (step + 1) % 25 == 0:
                save_checkpoint(ckpt_dir, step + 1, {"params": params, "opt": opt})
        return params, opt, m

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    print("phase 1: train to step 60, checkpointing every 25")
    params, opt, m1 = train(60, params, opt, 0)
    loss_at_60 = float(m1["loss"])

    print("simulated crash!  restarting from the latest checkpoint "
          f"(step {latest_step(ckpt_dir)})")
    fresh_params = model.init(jax.random.PRNGKey(0))
    fresh_opt = adamw_init(fresh_params)
    restored, start = restore_checkpoint(ckpt_dir,
                                         {"params": fresh_params, "opt": fresh_opt})
    print(f"phase 2: resume from step {start} and catch up")
    p2, o2, m2 = train(60, restored["params"], restored["opt"], start)
    print(f"loss before crash: {loss_at_60:.6f}  after resume: "
          f"{float(m2['loss']):.6f}  identical: "
          f"{loss_at_60 == float(m2['loss'])}")

    print("phase 3: continue to step 120")
    train(120, p2, o2, 60)
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
