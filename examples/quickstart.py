"""Quickstart: serve a small model with batched requests through the DéjàVu
pipeline-parallel cluster (the paper's kind of workload, end to end).

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    # GPT2-family reduced config (the paper's Fig.-4 model family), 8 layers
    cfg = dataclasses.replace(get_arch("gpt2-1.5b").reduced(), num_layers=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                max_new=8)
        for i in range(6)
    ]

    # 4 pipeline stages, colocated (the paper's baseline deployment)
    engine = ServingEngine(cfg, model, params, n_workers=4, microbatch=2)
    report = engine.run(requests)

    print(f"executed {report.steps_executed} pipeline steps")
    for rid in sorted(report.tokens):
        print(f"request {rid}: generated {report.tokens[rid]}")
    print("transfer bytes by transport:", engine.transfer_summary())


if __name__ == "__main__":
    main()
