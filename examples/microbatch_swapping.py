"""Microbatch swapping (paper §4.2.2): all in-flight microbatches' KV caches
live in host memory; only the active slots are device-resident.  The swap
path uses the Pallas kv_pack kernel (buffered copies) so each writeback is
ONE contiguous transfer instead of per-layer slices.

    PYTHONPATH=src python examples/microbatch_swapping.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import costmodel as cm
from repro.core.planner import MachineSpec
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    # memory accounting at paper scale: why swapping unlocks 2x batches
    full = get_arch("opt-66b")
    mach = MachineSpec()
    wl = cm.WorkloadSpec(1000, 220, 32)
    kv_all = full.decode_state_bytes(1220) * wl.microbatch      # one microbatch
    d = 4
    resident_all = d * kv_all / d                                # all-resident/stage
    resident_swap = 2 * kv_all / d                               # 2 slots/stage
    print(f"OPT-66B b=32: per-stage KV all-resident={resident_all/1e9:.1f}GB, "
          f"with swapping={resident_swap/1e9:.1f}GB "
          f"(machine budget {mach.mem_bytes/1e9:.0f}GB)")

    # real run: swapping produces identical tokens; hostlink bytes move
    cfg = dataclasses.replace(get_arch("gpt2-1.5b").reduced(), num_layers=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab_size, (4, 10)).astype(np.int32)

    def reqs():
        return [Request(rid=i, prompt=prompts[i].copy(), max_new=6)
                for i in range(4)]

    base = ServingEngine(cfg, model, params, 4, microbatch=2).run(reqs())
    eng = ServingEngine(cfg, model, params, 4, microbatch=2, swapping=True)
    rep = eng.run(reqs())
    print("tokens identical with swapping:", rep.tokens == base.tokens)
    print("host-link (PCIe-role) bytes:", eng.transfer_summary()["hostlink"])


if __name__ == "__main__":
    main()
