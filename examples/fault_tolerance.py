"""Fault tolerance (paper §4.2.3): token-level ring replication + 4-step
recovery.  A stage is killed mid-generation; the controller detects the
missing heartbeat, restores the lost KV from the ring successor's replica,
and generation resumes from the last replicated token — regenerating tokens
bit-identical to a failure-free run.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    cfg = dataclasses.replace(get_arch("gpt2-1.5b").reduced(), num_layers=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, (4, 10)).astype(np.int32)

    def reqs():
        return [Request(rid=i, prompt=prompts[i].copy(), max_new=8)
                for i in range(4)]

    ref = ServingEngine(cfg, model, params, 4, microbatch=2).run(reqs())

    eng = ServingEngine(cfg, model, params, 4, microbatch=2, replication=True)
    rep = eng.run(reqs(), fail_at={13: 2})     # kill worker 2 at step 13

    print(f"failures={rep.failures} recoveries={rep.recoveries} "
          f"steps_redone={rep.steps_redone}")
    print("tokens identical to failure-free run:", rep.tokens == ref.tokens)
    for ev in eng.cluster.controller.events:
        print("controller event:", {k: v for k, v in ev.items() if k != "t"})

    # straggler mitigation reuses the same machinery (beyond-paper)
    eng2 = ServingEngine(cfg, model, params, 4, microbatch=2, replication=True)
    rep2 = eng2.run(reqs(), migrate_at={9: 1})
    print("straggler migration keeps tokens identical:",
          rep2.tokens == ref.tokens)


if __name__ == "__main__":
    main()
