#!/usr/bin/env python
"""Docs drift gate: every relative link in the repo's markdown must resolve.

Scans README.md, docs/*.md, and benchmarks/README.md for markdown links
``[text](target)`` and checks that every non-URL target exists relative to
the file that references it.  Anchors are validated too: a ``#fragment``
(bare or on a ``file.md#fragment`` link into another scanned markdown
file) must match a heading's GitHub-style slug in the target document.
http(s)/mailto links are skipped.  Exits non-zero listing every dangling
link.  CI runs this next to ``python -m compileall src`` so a renamed
module, document, or section heading fails fast.
"""
from __future__ import annotations

import glob
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*$", re.M)
SKIP_PREFIXES = ("http://", "https://", "mailto:")


def doc_files(root: str):
    files = [os.path.join(root, "README.md"),
             os.path.join(root, "benchmarks", "README.md")]
    files += sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def _strip_code(text: str) -> str:
    # fenced code blocks routinely contain pseudo-links (e.g. arrays) — skip
    return re.sub(r"```.*?```", "", text, flags=re.S)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code ticks, lowercase,
    drop everything but word chars/spaces/hyphens, spaces -> hyphens."""
    h = re.sub(r"[*_`]", "", heading)
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)    # [text](url) -> text
    h = h.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def anchors_of(path: str, cache: dict) -> set:
    if path not in cache:
        try:
            text = _strip_code(open(path, encoding="utf-8").read())
        except OSError:
            cache[path] = set()
        else:
            cache[path] = {github_slug(m.group(2))
                           for m in HEADING_RE.finditer(text)}
    return cache[path]


def check_file(path: str, anchor_cache: dict):
    bad = []
    text = _strip_code(open(path, encoding="utf-8").read())
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel, _, frag = target.partition("#")
        resolved = path if not rel else os.path.normpath(
            os.path.join(os.path.dirname(path), rel))
        if rel and not os.path.exists(resolved):
            bad.append((target, f"missing '{resolved}'"))
            continue
        if frag and resolved.endswith(".md"):
            if frag not in anchors_of(resolved, anchor_cache):
                bad.append((target, f"no heading '#{frag}' in '{resolved}'"))
    return bad


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    failures = []
    files = doc_files(root)
    anchor_cache: dict = {}
    for f in files:
        for target, why in check_file(f, anchor_cache):
            why = why.replace(root + os.sep, "")
            failures.append(f"{os.path.relpath(f, root)}: link '{target}' "
                            f"-> {why}")
    if failures:
        print("dangling documentation links:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"docs check: {len(files)} files, all links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
