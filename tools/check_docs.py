#!/usr/bin/env python
"""Docs drift gate: every relative link in the repo's markdown must resolve.

Scans README.md, docs/*.md, and benchmarks/README.md for markdown links
``[text](target)`` and checks that every non-URL target exists relative to
the file that references it (anchors are stripped; bare #anchors and
http(s)/mailto links are skipped).  Exits non-zero listing every dangling
link.  CI runs this next to ``python -m compileall src`` so a renamed
module or document fails fast.
"""
from __future__ import annotations

import glob
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(root: str):
    files = [os.path.join(root, "README.md"),
             os.path.join(root, "benchmarks", "README.md")]
    files += sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def check_file(path: str):
    bad = []
    text = open(path, encoding="utf-8").read()
    # fenced code blocks routinely contain pseudo-links (e.g. arrays) — skip
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            bad.append((target, resolved))
    return bad


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    failures = []
    files = doc_files(root)
    for f in files:
        for target, resolved in check_file(f):
            failures.append(f"{os.path.relpath(f, root)}: link '{target}' "
                            f"-> missing '{os.path.relpath(resolved, root)}'")
    if failures:
        print("dangling documentation links:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"docs check: {len(files)} files, all links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
