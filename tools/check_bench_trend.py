#!/usr/bin/env python
"""Benchmark-trend gate: compare ``emit_metric`` rows against a baseline.

Reads every ``repro.bench/v1`` artifact in a directory (the
``BENCH_JSON_DIR`` a benchmark run just wrote) and compares each NUMERIC
row — the ones emitted via ``benchmarks.common.emit_metric`` — against the
committed baseline ``benchmarks/baselines/BENCH_baseline.json``
(``repro.bench_baseline/v1``)::

    {"schema": "repro.bench_baseline/v1",
     "metrics": {"<module-stem>/<row-name>":
                 {"value": <float>, "rel_tol": <float>,
                  "direction": "higher_better"|"lower_better"|"two_sided"}}}

Semantics, per metric:

- ``higher_better``: fail when measured < baseline * (1 - rel_tol)
  (improvements never fail; re-baseline to ratchet).
- ``lower_better``:  fail when measured > baseline * (1 + rel_tol)
- ``two_sided``:     fail when |measured - baseline| > |baseline| * rel_tol

Modules whose JSON artifact is absent from the run directory are skipped
(fast-suite CI only runs a subset), but a baseline metric whose module
artifact IS present must appear in it — a silently dropped metric is a
failure, not a skip.  New metrics not in the baseline are reported as
informational (add them by re-baselining).

Re-baselining (after an intentional perf/model change)::

    BENCH_JSON_DIR=bench-json python -m benchmarks.run
    python tools/check_bench_trend.py bench-json --update
    git add benchmarks/baselines/BENCH_baseline.json

Exit codes: 0 ok, 1 regression/missing metric, 2 usage or schema error.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

BASELINE_SCHEMA = "repro.bench_baseline/v1"
BENCH_SCHEMA = "repro.bench/v1"
DEFAULT_REL_TOL = 0.05
DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks", "baselines", "BENCH_baseline.json")
DIRECTIONS = ("higher_better", "lower_better", "two_sided")


def load_run_metrics(run_dir: str):
    """``{"<module-stem>/<row-name>": value}`` over every artifact in
    `run_dir`, plus the set of module stems that produced an artifact."""
    metrics, modules = {}, set()
    for path in sorted(glob.glob(os.path.join(run_dir, "*.json"))):
        stem = os.path.splitext(os.path.basename(path))[0]
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
        if not isinstance(doc, dict) or doc.get("schema") != BENCH_SCHEMA:
            continue                      # foreign JSON in the dir; ignore
        modules.add(stem)
        for row in doc.get("rows", ()):
            if "value" in row:            # emit_metric rows only
                metrics[f"{stem}/{row['name']}"] = float(row["value"])
    return metrics, modules


def load_baseline(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"{path}: expected schema {BASELINE_SCHEMA!r}, "
                         f"got {doc.get('schema')!r}")
    for key, spec in doc.get("metrics", {}).items():
        if spec.get("direction", "two_sided") not in DIRECTIONS:
            raise ValueError(f"{path}: metric {key!r} has unknown direction "
                             f"{spec.get('direction')!r}")
    return doc


def check_metric(key: str, measured: float, spec: dict):
    """Return (ok, detail-string) for one baseline entry."""
    base = float(spec["value"])
    tol = float(spec.get("rel_tol", DEFAULT_REL_TOL))
    direction = spec.get("direction", "two_sided")
    if measured != measured:              # NaN never passes
        return False, f"{key}: measured NaN (baseline {base:g})"
    if direction == "higher_better":
        floor = base * (1.0 - tol)
        ok = measured >= floor
        detail = f"{key}: {measured:g} < floor {floor:g} (baseline {base:g})"
    elif direction == "lower_better":
        ceil = base * (1.0 + tol)
        ok = measured <= ceil
        detail = f"{key}: {measured:g} > ceiling {ceil:g} (baseline {base:g})"
    else:
        ok = abs(measured - base) <= abs(base) * tol
        detail = (f"{key}: {measured:g} outside +/-{tol:.0%} "
                  f"of baseline {base:g}")
    return ok, detail


def update_baseline(path: str, metrics: dict, prev: dict) -> dict:
    """Refresh values for measured metrics; keep tolerances/directions and
    entries for modules that did not run; add new metrics at defaults."""
    out = {k: dict(v) for k, v in prev.get("metrics", {}).items()}
    for key, value in metrics.items():
        spec = out.setdefault(
            key, {"rel_tol": DEFAULT_REL_TOL, "direction": "two_sided"})
        spec["value"] = value
    return {"schema": BASELINE_SCHEMA, "metrics": out}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("run_dir", help="directory of repro.bench/v1 artifacts "
                    "(a benchmark run's BENCH_JSON_DIR)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this run instead of "
                    "checking against it")
    args = ap.parse_args(argv)

    if not os.path.isdir(args.run_dir):
        print(f"check_bench_trend: run dir {args.run_dir!r} does not exist",
              file=sys.stderr)
        return 2
    metrics, modules = load_run_metrics(args.run_dir)

    if args.update:
        prev = {}
        if os.path.exists(args.baseline):
            try:
                prev = load_baseline(args.baseline)
            except ValueError as e:
                print(f"check_bench_trend: {e}", file=sys.stderr)
                return 2
        doc = update_baseline(args.baseline, metrics, prev)
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"check_bench_trend: baseline updated with "
              f"{len(metrics)} metric(s) -> {args.baseline}")
        return 0

    try:
        baseline = load_baseline(args.baseline)
    except (OSError, ValueError) as e:
        print(f"check_bench_trend: cannot load baseline: {e}",
              file=sys.stderr)
        return 2

    failures, checked, skipped = [], 0, 0
    for key, spec in sorted(baseline["metrics"].items()):
        stem = key.split("/", 1)[0]
        if stem not in modules:
            skipped += 1                  # module did not run in this suite
            continue
        if key not in metrics:
            failures.append(f"{key}: metric missing from {stem}.json "
                            f"(module ran; was the emit_metric row removed?)")
            continue
        checked += 1
        ok, detail = check_metric(key, metrics[key], spec)
        if not ok:
            failures.append(detail)
    new = sorted(k for k in metrics if k not in baseline["metrics"])
    if new:
        print(f"check_bench_trend: {len(new)} metric(s) not in baseline "
              f"(informational): {', '.join(new)}")
    for f_ in failures:
        print(f"REGRESSION {f_}", file=sys.stderr)
    print(f"check_bench_trend: {checked} checked, {skipped} skipped "
          f"(module absent), {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
