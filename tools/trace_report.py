#!/usr/bin/env python
"""Replay a flight-recorder dump (``repro.trace/v1``) into a per-request
critical-path breakdown and a bubble-attribution table.

The modeled clock only advances inside ``pass`` spans (every
``telemetry.advance`` in the serving stack is charged inside one), so a
request's admit→retire window decomposes exactly into the pass spans that
overlap it.  Per request, each overlapping serve-track span is attributed
to one phase:

- ``queue``     — arrival → admission (the ``sched.admit`` ``wait_ns``)
- ``prefill``   — pass spans of a prefill kind that include the request
- ``decode``    — pass spans of a decode kind that include the request
- ``stall_prompt`` — prefill-kind passes of OTHER requests inside the
  window: the prompt-induced pipeline bubble of the paper's Fig. 4
- ``stall_decode`` — decode-kind passes of other requests (batch slots
  the request couldn't join)
- ``recovery``  — ``recovery`` spans (worker rebuild after a failure)
- ``residual``  — window time no span claims (explicitly reported)

Streamed transfers (``xfer`` events) never advance the modeled clock —
they model DMA/network time overlapped with compute — so they are
reported per-kind as an informational overlay, not part of the wall-time
denominator.

``--assert`` exits non-zero unless every request's named-phase coverage
is ≥ ``--min-coverage`` (CI gate); with ``--compare BASELINE`` it also
asserts this trace's prompt-induced bubble share is no worse than the
baseline's (the disagg-vs-coupled claim).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

TRACE_SCHEMA = "repro.trace/v1"

PREFILL_KINDS = ("mb_prefill", "prefill_batch", "prefill_chunk",
                 "prefill_token", "chunkset")
DECODE_KINDS = ("mb_decode", "perseq_decode", "fused_decode")

PHASES = ("queue", "prefill", "decode", "stall_prompt", "stall_decode",
          "recovery", "residual")


def _involves(ev: dict, rid: int) -> bool:
    if ev.get("rid") == rid:
        return True
    rids = ev.get("args", {}).get("rids")
    return rids is not None and rid in rids


def _phase_of(ev: dict, rid: int) -> Optional[str]:
    if ev["name"] == "recovery":
        return "recovery"
    if ev["name"] != "pass":
        return None
    kind = ev.get("args", {}).get("kind", "")
    mine = _involves(ev, rid)
    if kind in PREFILL_KINDS or kind.startswith("prefill"):
        return "prefill" if mine else "stall_prompt"
    if kind in DECODE_KINDS or "decode" in kind:
        return "decode" if mine else "stall_decode"
    return None


def analyze(trace: Dict[str, object]) -> Dict[str, object]:
    """Pure analysis: trace dump -> {requests, bubbles, streams, dropped}."""
    if trace.get("schema") != TRACE_SCHEMA:
        raise ValueError(f"expected a {TRACE_SCHEMA} dump, "
                         f"got {trace.get('schema')!r}")
    tracks = trace.get("tracks", {})
    serve = tracks.get("serve", {"events": [], "dropped": 0})
    events = serve["events"]

    # request lifecycle boundaries from scheduler events
    admits: Dict[int, dict] = {}
    ends: Dict[int, int] = {}
    for ev in events:
        rid = ev.get("rid")
        if rid is None:
            continue
        if ev["name"] == "sched.admit":
            admits.setdefault(rid, ev)
        end = ev["ts"] + ev.get("dur", 0)
        ends[rid] = max(ends.get(rid, end), end)
    # passes that include a request can outlast its last own event
    spans = [ev for ev in events if ev["name"] in ("pass", "recovery")]
    for ev in spans:
        for rid in list(ends):
            if _involves(ev, rid):
                ends[rid] = max(ends[rid], ev["ts"] + ev.get("dur", 0))

    requests = {}
    for rid, admit in sorted(admits.items()):
        t0, t1 = admit["ts"], ends.get(rid, admit["ts"])
        wait = int(admit.get("args", {}).get("wait_ns", 0))
        phases = {p: 0 for p in PHASES}
        phases["queue"] = wait
        for ev in spans:
            lo = max(ev["ts"], t0)
            hi = min(ev["ts"] + ev.get("dur", 0), t1)
            if hi <= lo:
                continue
            ph = _phase_of(ev, rid)
            if ph is not None:
                phases[ph] += hi - lo
        window = t1 - t0
        named = sum(phases[p] for p in PHASES if p != "residual")
        phases["residual"] = max(window + wait - named, 0)
        wall = window + wait
        requests[rid] = {
            "admit_ns": t0,
            "end_ns": t1,
            "wall_ns": wall,
            "phases": phases,
            "coverage": (named / wall) if wall > 0 else 1.0,
        }

    # Fig. 4 bubble taxonomy, aggregated over requests
    tot = {p: sum(r["phases"][p] for r in requests.values()) for p in PHASES}
    wall_total = sum(r["wall_ns"] for r in requests.values())
    bubbles = {
        "prompt_induced_ns": tot["stall_prompt"],
        "decode_stall_ns": tot["stall_decode"],
        "recovery_ns": tot["recovery"],
        "queue_ns": tot["queue"],
        "wall_total_ns": wall_total,
        "prompt_bubble_share": (tot["stall_prompt"] / wall_total
                                if wall_total else 0.0),
    }

    # informational: streamed/transferred modeled time per track (never in
    # the wall-time denominator — it models overlapped DMA/network time)
    streams = {}
    for tname, tr in tracks.items():
        xfer_ns = sum(ev.get("dur", 0) for ev in tr["events"]
                      if ev["name"] in ("xfer", "stream.task"))
        if xfer_ns:
            streams[tname] = xfer_ns

    return {
        "requests": requests,
        "bubbles": bubbles,
        "streams_ns": streams,
        "dropped": {t: tr["dropped"] for t, tr in tracks.items()
                    if tr["dropped"]},
    }


def _ms(ns: int) -> str:
    return f"{ns / 1e6:10.3f}"


def render(report: Dict[str, object]) -> str:
    lines: List[str] = []
    lines.append("per-request critical path (ms on the modeled clock)")
    hdr = f"{'rid':>4} {'wall':>10} " + " ".join(f"{p:>12}" for p in PHASES) \
        + f" {'coverage':>9}"
    lines.append(hdr)
    for rid, r in report["requests"].items():
        row = f"{rid:>4} {_ms(r['wall_ns'])} " + " ".join(
            f"{_ms(r['phases'][p]):>12}" for p in PHASES)
        lines.append(row + f" {r['coverage'] * 100:8.2f}%")
    b = report["bubbles"]
    lines.append("")
    lines.append("bubble attribution (paper Fig. 4 taxonomy)")
    for key in ("prompt_induced_ns", "decode_stall_ns", "recovery_ns",
                "queue_ns"):
        share = b[key] / b["wall_total_ns"] if b["wall_total_ns"] else 0.0
        lines.append(f"  {key[:-3]:<16} {_ms(b[key])} ms  "
                     f"({share * 100:5.2f}% of request wall time)")
    lines.append(f"  prompt_bubble_share = {b['prompt_bubble_share']:.4f}")
    if report["streams_ns"]:
        lines.append("")
        lines.append("overlapped streaming (informational, not wall time)")
        for t, ns in sorted(report["streams_ns"].items()):
            lines.append(f"  {t:<10} {_ms(ns)} ms")
    if report["dropped"]:
        lines.append("")
        lines.append(f"WARNING: ring-buffer drops: {report['dropped']} "
                     "(dump is truncated; raise Tracer(capacity=...))")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="repro.trace/v1 JSON dump")
    ap.add_argument("--compare", metavar="BASELINE",
                    help="baseline trace: assert prompt-bubble share is "
                         "no worse than it (with --assert)")
    ap.add_argument("--assert", dest="do_assert", action="store_true",
                    help="exit non-zero on coverage/bubble violations")
    ap.add_argument("--min-coverage", type=float, default=0.95,
                    help="per-request named-phase coverage floor "
                         "(default 0.95)")
    ap.add_argument("--json", action="store_true",
                    help="emit the analysis as JSON instead of tables")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        report = analyze(json.load(f))
    if args.json:
        print(json.dumps(report, sort_keys=True, indent=1))
    else:
        print(render(report))

    failures: List[str] = []
    if args.do_assert:
        if not report["requests"]:
            failures.append("trace contains no admitted requests")
        for rid, r in report["requests"].items():
            if r["coverage"] < args.min_coverage:
                failures.append(
                    f"request {rid}: coverage {r['coverage']:.4f} < "
                    f"{args.min_coverage} "
                    f"(residual {r['phases']['residual']} ns)")
        if args.compare:
            with open(args.compare) as f:
                base = analyze(json.load(f))
            mine = report["bubbles"]["prompt_bubble_share"]
            theirs = base["bubbles"]["prompt_bubble_share"]
            if mine > theirs + 1e-9:
                failures.append(
                    f"prompt bubble share regressed: {mine:.4f} > "
                    f"baseline {theirs:.4f}")
    if failures:
        print("\nTRACE GATE FAILURES:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
