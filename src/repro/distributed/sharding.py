"""Name-based sharding rules for the production mesh.

Mesh axes: ("data", "model") single-pod / ("pod", "data", "model") multi-pod.

  batch        → (pod, data)
  heads/ff/vocab/experts (weight columns) → model        (tensor / expert par.)
  d_model rows of big-arch weights        → (pod, data)  (FSDP / ZeRO-style)
  decode KV cache: batch → (pod, data), seq → model      (flash-decode style
      sequence sharding: avoids padding waste for kv_heads ∤ 16 and keeps
      per-chip KV under HBM limits at 32k contexts)
  long_500k (batch=1): full-attn KV seq → data, SSM heads → model

FSDP kicks in when bf16 params exceed `FSDP_THRESHOLD_BYTES` (the weights no
longer fit replicated per-chip next to activations).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig

FSDP_THRESHOLD_BYTES = 4e9


def fsdp_enabled(cfg: ArchConfig) -> bool:
    return cfg.param_count() * 2 > FSDP_THRESHOLD_BYTES


def _axes(mesh: Mesh):
    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    return dp, "model"


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return dim % n == 0


def param_spec(path: str, shape, cfg: ArchConfig, mesh: Mesh,
               variant: str = "baseline") -> P:
    dp, mp = _axes(mesh)
    fsdp = dp if fsdp_enabled(cfg) else None
    leaf = path.split("/")[-1]
    container = path.split("/")[-2] if "/" in path else ""

    def dprow(dim):  # FSDP-shard a d_model-sized dim if divisible
        return fsdp if (fsdp and _divisible(dim, mesh, fsdp)) else None

    def mcol(dim):
        return mp if _divisible(dim, mesh, mp) else None

    if leaf in ("embed",):
        if variant == "opt-rowssm" and mcol(shape[0]) is None \
                and dprow(shape[1]) is None and _divisible(shape[1], mesh, mp):
            # vocab not divisible by TP width: shard d_model instead so the
            # (tied) head matmul partial-sums with a tiny psum
            return P(None, mp)
        return P(mcol(shape[0]), dprow(shape[1]))
    if leaf == "lm_head":
        if variant == "opt-rowssm" and mcol(shape[1]) is None \
                and dprow(shape[0]) is None and _divisible(shape[0], mesh, mp):
            return P(mp, None)
        return P(dprow(shape[0]), mcol(shape[1]))
    if leaf in ("pos_table", "src_pos", "meta", "patch_proj"):
        return P(*([None] * len(shape)))
    if container == "attn" or container == "cross":
        if leaf in ("wq", "wk", "wv"):
            return P(None, dprow(shape[1]), mcol(shape[2]))
        if leaf == "wo":
            return P(None, mcol(shape[1]), dprow(shape[2]))
    if container == "mlp":
        if variant == "opt-zmlp":
            # ZeRO-style MLP: weights FSDP-only (gathered per layer); tokens
            # seq-sharded over `model` -> no ff-contraction all-reduce
            if leaf in ("w_gate", "w_up"):
                return P(None, dprow(shape[1]), None)
            if leaf == "w_down":
                return P(None, None, dprow(shape[2]))
        if leaf in ("w_gate", "w_up"):
            return P(None, dprow(shape[1]), mcol(shape[2]))
        if leaf == "w_down":
            return P(None, mcol(shape[1]), dprow(shape[2]))
    if container == "moe":
        if leaf == "router":
            return P(None, None, None)
        if leaf in ("w_gate", "w_up"):
            return P(None, mcol(shape[1]), dprow(shape[2]), None)   # experts → model
        if leaf == "w_down":
            return P(None, mcol(shape[1]), None, dprow(shape[3]))
    if container == "ssm":
        if variant == "opt-rowssm" and leaf in ("w_in", "w_out"):
            # batch=1 decode is weight-traffic-bound: shard weight ROWS over
            # `model` (1/16 weight reads/chip, tiny psum of the output) —
            # row sharding doesn't conflict with the z/x/B/C column slices
            return P(None, mcol(shape[1]), None)
        if leaf in ("w_in", "w_out"):
            return P(None, dprow(shape[1]), None)
        return P(*([None] * len(shape)))
    # norms, scalars, fuse scales, conv weights: replicated
    return P(*([None] * len(shape)))


def _tree_with_paths(tree, fn, prefix=""):
    if isinstance(tree, dict):
        return {k: _tree_with_paths(v, fn, f"{prefix}{k}/") for k, v in tree.items()}
    if hasattr(tree, "_asdict"):
        return type(tree)(**{k: _tree_with_paths(v, fn, f"{prefix}{k}/")
                             for k, v in tree._asdict().items()})
    return fn(prefix[:-1], tree)


def param_shardings(params_shapes, cfg: ArchConfig, mesh: Mesh,
                    variant: str = "baseline"):
    """NamedSharding tree for a params (or optimizer m/v) pytree of
    ShapeDtypeStructs."""
    def mk(path, leaf):
        # strip the leading container for optimizer trees (m/, v/)
        p = path
        for pre in ("m/", "v/"):
            if p.startswith(pre):
                p = p[len(pre):]
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, param_spec(p, leaf.shape, cfg, mesh, variant))
    return _tree_with_paths(params_shapes, mk)


def batch_shardings(batch_shapes, cfg: ArchConfig, mesh: Mesh):
    dp, _ = _axes(mesh)

    def mk(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = [None] * leaf.ndim
        if leaf.shape[0] % _size(mesh, dp) == 0:
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))
    return _tree_with_paths(batch_shapes, mk)


def _size(mesh: Mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def state_shardings(state_shapes, cfg: ArchConfig, mesh: Mesh,
                    batch: int) -> Dict:
    """Decode-state sharding: batch → dp when divisible; else (batch=1 long
    context) shard the seq/window axis over data and heads over model."""
    dp, mp = _axes(mesh)
    dp_n = _size(mesh, dp)
    mp_n = mesh.shape[mp]

    def mk(path, leaf):
        if isinstance(leaf, tuple):      # (shape, dtype) form
            leaf = jax.ShapeDtypeStruct(leaf[0], jnp.dtype(leaf[1]))
        spec = [None] * leaf.ndim
        name = path.split("/")[-1]
        if path == "swa_pos" or leaf.ndim <= 1:
            return NamedSharding(mesh, P(*spec))
        if path.startswith(("kv", "cross")):
            # [L, B, S, H, D]
            if batch % dp_n == 0 and batch >= dp_n:
                spec[1] = dp
                if leaf.shape[2] % mp_n == 0:
                    spec[2] = mp                      # seq → model
            else:
                if leaf.shape[2] % dp_n == 0:
                    spec[2] = dp                      # long-context: seq → data
            return NamedSharding(mesh, P(*spec))
        if path == "ssd":
            # [L, B, nh, hd, N]
            if batch % dp_n == 0 and batch >= dp_n:
                spec[1] = dp
            if leaf.shape[2] % mp_n == 0:
                spec[2] = mp
            return NamedSharding(mesh, P(*spec))
        if path == "conv":
            # [L, B, K-1, conv_dim] — conv_dim stays UNSHARDED: the state is
            # tiny and its x/B/C part boundaries don't align with 1/16 shards
            # (sharding it forces involuntary full remats on every slice)
            if batch % dp_n == 0 and batch >= dp_n:
                spec[1] = dp
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P(*spec))
    return _tree_with_paths(state_shapes, mk)


def activation_rules(mesh: Mesh, variant: str = "baseline",
                     kind: str = "train") -> Optional[Dict[str, object]]:
    """Logical-axis rules installed into models.common (hillclimb lever).

    baseline: None — no activation constraints; GSPMD propagates from the
              weight/IO shardings alone (the paper-faithful starting point).
    opt:      explicit tensor-parallel activations — heads/kv_heads/ff/
              experts/vocab → model (GSPMD pads non-divisible head counts),
              d_inner/ssm groups → model (Mamba inner parallelism), and
              seq → model on the residual stream for train (Megatron-style
              sequence parallelism: saved activations shrink 16×).
    """
    if variant == "baseline":
        return None
    dp, mp = _axes(mesh)
    rules = {"batch": dp, "heads": mp, "kv_heads": mp, "ff": mp,
             "vocab": mp, "experts": mp, "d_inner": mp, "ssm_gn": None,
             "ssm_heads": mp, "seq": mp if kind == "train" else None,
             "__sizes__": {a: int(mesh.shape[a]) for a in mesh.axis_names}}
    if variant == "opt-zmlp":
        rules["ff"] = None
        rules["mlp_seq"] = mp
    return rules
