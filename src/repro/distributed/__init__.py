from repro.distributed.sharding import (param_shardings, batch_shardings,
                                        state_shardings, fsdp_enabled,
                                        activation_rules)

__all__ = ["param_shardings", "batch_shardings", "state_shardings",
           "fsdp_enabled", "activation_rules"]
