from repro.distributed.sharding import (activation_rules, batch_shardings,
                                        fsdp_enabled, param_shardings,
                                        state_shardings)

__all__ = ["param_shardings", "batch_shardings", "state_shardings",
           "fsdp_enabled", "activation_rules"]
