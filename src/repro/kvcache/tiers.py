"""Tiered KV-cache hierarchy: HBM block pool → host RAM → SSD.

`KVTierManager` unifies the paged `BlockPool`/`PagedKVCache` (tier 0, device
HBM) with a `HostMemoryStore` (tier 1, pinned host RAM) and an `SSDStore`
(tier 2, local NVMe) behind one block-granular API, the DéjàVu idea of
hiding cache movement across a memory hierarchy behind compute:

  demotion   cold blocks move DOWN-tier as asynchronous *write-behind* on the
             shared `StreamEngine`, so the modeled transfer time overlaps the
             next steps' compute instead of stalling the pipeline;
  promotion  a needed block moves UP-tier on demand; the rest of its
             sequence's block chain is *prefetched* behind the first fetch,
             so only the head of the chain is an exposed stall;
  prefix     full prompt blocks are indexed by their prefix-chain hash
             (`BlockPool.chain_hashes`) when their sequence retires, so a NEW
             request whose prompt shares the prefix streams those blocks back
             in from whatever tier holds them instead of re-prefilling.

Two kinds of entry live in the hierarchy:

  ``pfx/<hash>``            immutable full prompt blocks, keyed by content —
                            re-creatable by prefill, so they may be dropped
                            under tier-2 pressure (LRU);
  ``tswap/seq<i>/blk<j>``   a preempted/swapped sequence's live blocks —
                            possibly the only copy, so they spill to SSD but
                            are never dropped (over-commit is recorded).

A block's bytes are packed as one ``[2, Lstage, w, Hkv, Dh]`` array (K
stacked on V) so every store holds exactly one object per block and a spill
can never tear a block across tiers.  All *bookkeeping* (index, LRU order,
eviction planning) happens synchronously on the caller's thread; only the
*data movement* closures run on the streamer, and every read path drains the
streamer first, so reads always observe completed writes.

Tier 2 survives worker death (it is disk): `reattach()` rebuilds the index
from the self-describing SSD keys, which is how failure recovery restores
state from the lowest tier holding a replica (see
`DejaVuCluster._recover_worker_paged`).
"""
from __future__ import annotations

import tempfile
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import telemetry
from repro.core import tracing
from repro.core.dejavulib import faults
from repro.core.dejavulib.buffers import HostMemoryStore, SSDStore
from repro.core.dejavulib.streamer import StreamEngine
from repro.core.dejavulib.transport import (DEFAULT_HW, HardwareModel,
                                            HostLinkTransport, SSDTransport)
from repro.kvcache.paged import BlockPool, PagedKVCache

TIER_HBM, TIER_HOST, TIER_SSD = 0, 1, 2


@dataclass(frozen=True)
class TierConfig:
    """Per-stage capacities of the off-device tiers, in KV blocks."""
    host_capacity_blocks: Optional[int] = None   # None = unbounded
    ssd_capacity_blocks: Optional[int] = None    # None = unbounded
    ssd_root: Optional[str] = None               # None = private tempdir


@dataclass
class _Entry:
    key: str            # store key (same string in every tier)
    kind: str           # "pfx" | "swap"
    tier: int           # fastest off-device tier currently holding the bytes
    on_ssd: bool        # a (possibly additional) copy exists on disk
    nbytes: int
    seq: int = -1       # swap entries only
    j: int = -1         # swap entries only


class KVTierManager:
    """Block-granular movement between one stage's HBM pool and its
    host/SSD tiers.  One instance per `StageWorker` (each stage caches its
    own layer slice of every block, keyed by the same prefix hash)."""

    def __init__(self, pool: BlockPool, pages: PagedKVCache,
                 streamer: StreamEngine, hw: HardwareModel = DEFAULT_HW,
                 cfg: TierConfig = TierConfig(), name: str = "tier"):
        self.pool = pool
        self.pages = pages
        self.streamer = streamer
        self.cfg = cfg
        self.name = name
        cap = (None if cfg.host_capacity_blocks is None
               else cfg.host_capacity_blocks * pages.block_bytes)
        # capacity backstop: the manager plans placement in whole blocks, so
        # a raise here means the planner's accounting is wrong — fail loud
        self.host = HostMemoryStore(f"{name}-tier1", capacity_bytes=cap)
        root = cfg.ssd_root or tempfile.mkdtemp(prefix=f"dejavu-{name}-ssd-")
        self.ssd = SSDStore(root, name=f"{name}-tier2")
        self.hostlink = HostLinkTransport(hw)
        self.ssdlink = SSDTransport(hw)
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()  # LRU order
        self._stats: Dict[str, float] = {}
        self._pending: List[object] = []   # in-flight streamer tasks
        self._pinned: set = set()          # keys a read-in-progress protects

    # ------------------------------------------------------------------
    # bookkeeping helpers (caller thread only)
    # ------------------------------------------------------------------
    def _bump(self, key: str, v: float = 1) -> None:
        self._stats[key] = self._stats.get(key, 0) + v
        # Mirror into the telemetry registry: time-valued keys accumulate
        # integer ns, event keys stay integer counters.
        if key.endswith("_s"):
            telemetry.count_time(f"tier.{key[:-2]}_ns", v)
        else:
            telemetry.count(f"tier.{key}", int(v))

    def _fault_point(self, point: str, tag: str) -> None:
        """Fire a tier injection point; a `delay` fault charges straggler
        time to the tier's modeled timeline (raising kinds propagate)."""
        spec = faults.fire(point, tag=tag)
        if spec is not None and spec.kind == "delay":
            self._bump("fault_delay_model_s", spec.delay_s)

    def _submit(self, fn, model_seconds: float = 0.0, tag: str = "") -> None:
        self._pending.append(self.streamer.submit(
            fn, model_seconds=model_seconds, tag=tag))
        if len(self._pending) > 64:     # bound the list (and the ndarrays
            self._reap()                # its closures pin) between reads

    def _reap(self) -> None:
        """Drop completed tasks, surfacing the first error any of them hit —
        a failed demotion must not silently strand an entry whose bytes
        never landed."""
        live, err = [], None
        for task in self._pending:
            if not task.done.is_set():
                live.append(task)
            elif task.error is not None and err is None:
                err = task
        self._pending = live
        if err is not None:
            raise RuntimeError(
                f"tier write-behind {err.tag!r} failed") from err.error

    def _sync(self) -> None:
        """Barrier before any read: wait for in-flight write-behinds and
        surface their errors."""
        try:
            self.streamer.drain()
        except faults.StreamTaskError:
            # our own write-behind failed: _reap re-raises it with tier
            # context (which key, which task) — the contract readers test
            self._reap()
            raise      # not ours (e.g. a replication send): propagate as-is
        self._reap()

    def _touch(self, key: str) -> None:
        self._entries.move_to_end(key)

    def _host_blocks(self) -> int:
        return sum(1 for e in self._entries.values() if e.tier == TIER_HOST)

    def _ssd_blocks(self) -> int:
        return sum(1 for e in self._entries.values() if e.on_ssd)

    @staticmethod
    def _pack(arrays: Dict[str, np.ndarray]) -> np.ndarray:
        return np.stack([np.asarray(arrays["k"]), np.asarray(arrays["v"])])

    @staticmethod
    def _unpack(arr: np.ndarray) -> Dict[str, np.ndarray]:
        return {"k": arr[0], "v": arr[1]}

    # ------------------------------------------------------------------
    # placement planning + async data movement
    # ------------------------------------------------------------------
    def _make_host_room(self, entry: _Entry) -> bool:
        """Spill LRU host entries to SSD until `entry` fits in tier 1.
        False when no room can be made (capacity 0, or every resident entry
        is pinned by a read in progress)."""
        cap = self.cfg.host_capacity_blocks
        if cap is not None and cap <= 0:
            return False
        need = 0 if entry.tier == TIER_HOST else 1
        while cap is not None and self._host_blocks() + need > cap:
            victim = next((e for e in self._entries.values()
                           if e.tier == TIER_HOST and e is not entry
                           and e.key not in self._pinned), None)
            if victim is None:
                return False
            self._spill_to_ssd(victim)
        return True

    def _admit_host(self, entry: _Entry, packed: np.ndarray) -> None:
        """Place `entry`'s bytes in tier 1 — or straight in tier 2 when no
        host room can be made; the actual copy is write-behind."""
        self._fault_point("tier.demote", entry.key)
        if tracing.active():
            tracing.event("tier.demote", key=entry.key,
                          blocks=1, dst="host")
        if not self._make_host_room(entry):
            self._admit_ssd(entry, packed)
            return
        entry.tier = TIER_HOST
        key, link = entry.key, self.hostlink

        def _put():
            self.host.put(key, link.transfer(packed, tag=key))

        self._bump("write_behind_model_s", link.model_time(packed.nbytes))
        self._submit(_put, model_seconds=link.model_time(packed.nbytes),
                     tag=f"tier-demote-{key}")

    def _admit_ssd(self, entry: _Entry, packed: np.ndarray) -> None:
        self._make_ssd_room(exclude=entry)
        entry.tier, entry.on_ssd = TIER_SSD, True
        key, link = entry.key, self.ssdlink

        def _put():
            self.ssd.put(key, link.transfer(packed, tag=key))

        self._bump("write_behind_model_s", link.model_time(packed.nbytes))
        self._submit(_put, model_seconds=link.model_time(packed.nbytes),
                     tag=f"tier-demote2-{key}")

    def _spill_to_ssd(self, entry: _Entry) -> None:
        """Demote one host-resident entry to tier 2 (write-behind)."""
        key = entry.key
        self._fault_point("tier.demote", f"spill-{key}")
        if tracing.active():
            tracing.event("tier.demote", key=key, blocks=1, dst="ssd")
        self._bump("spills")
        if entry.on_ssd:                    # disk already holds a copy
            entry.tier = TIER_SSD
            self._submit(lambda: self.host.delete(key),
                         tag=f"tier-drop1-{key}")
            return
        self._make_ssd_room(exclude=entry)
        entry.tier, entry.on_ssd = TIER_SSD, True
        link = self.ssdlink

        def _spill():
            # idempotent (a transient SSD-write fault retries the whole
            # closure): the host copy survives until the disk write is
            # durable, then retires — never pop-then-write
            arr = self.host.get(key)        # FIFO: the host put already ran
            self.ssd.put(key, link.transfer(arr, tag=key))
            self.host.delete(key)

        self._bump("write_behind_model_s", link.model_time(entry.nbytes))
        self._submit(_spill, model_seconds=link.model_time(entry.nbytes),
                     tag=f"tier-spill-{key}")

    def _make_ssd_room(self, exclude: Optional[_Entry] = None) -> None:
        cap = self.cfg.ssd_capacity_blocks
        while cap is not None and self._ssd_blocks() >= cap:
            # Only content-addressed prefix blocks are droppable (they can be
            # re-prefilled); swap blocks may be the only copy of live state.
            # Evict the NEWEST prefix block (reverse LRU order): chains are
            # demoted head-first, so MRU eviction sacrifices chain TAILS —
            # dropping a head (the LRU end) would strand its whole chain,
            # since adoption needs a leading run.
            victim = next((e for e in reversed(self._entries.values())
                           if e.on_ssd and e.kind == "pfx" and e is not exclude
                           and e.key not in self._pinned), None)
            if victim is None:
                self._bump("ssd_overcommit")
                return
            if victim.tier == TIER_HOST:
                # host still serves it: retiring just the disk copy frees
                # the SSD slot without evicting a hot block from everything
                victim.on_ssd = False
                self._submit(lambda k=victim.key: self.ssd.delete(k),
                             tag=f"tier-unpersist-{victim.key}")
                self._bump("ssd_copy_retired")
            else:
                self._drop(victim)

    def _drop(self, entry: _Entry, evicted: bool = True) -> None:
        self._entries.pop(entry.key, None)
        key, on_host, on_ssd = entry.key, entry.tier == TIER_HOST, entry.on_ssd
        if evicted:
            self._bump("dropped")

        def _rm():
            if on_host:
                self.host.delete(key)
            if on_ssd:
                self.ssd.delete(key)

        self._submit(_rm, tag=f"tier-evict-{key}")

    def _read(self, entry: _Entry) -> np.ndarray:
        """Synchronous up-tier read of one entry (caller synced first).
        Returns the transferred copy and refreshes LRU/tier state."""
        key = entry.key
        self._fault_point("tier.promote", key)
        if tracing.active():
            tracing.event("tier.promote", key=key,
                          src="host" if entry.tier == TIER_HOST else "ssd")
        try:
            if entry.tier == TIER_HOST:
                arr = self.hostlink.transfer(self.host.get(key), tag=key)
                self._bump("host_hits")
                self._touch(key)
                return arr
        except KeyError as e:
            # the worker died mid-read and its host tier was wiped — surface
            # as the recoverable "worker lost" error class, not a KeyError
            raise RuntimeError(
                f"tier {self.name!r}: host entry {key!r} lost mid-read") from e
        # a promotion earlier in this chain may have scheduled a spill
        # whose SSD write has not landed yet — wait for the queue
        self._sync()
        try:
            arr = self.ssdlink.transfer(self.ssd.get(key), tag=key)
        except FileNotFoundError as e:
            raise RuntimeError(
                f"tier {self.name!r}: SSD entry {key!r} lost mid-read") from e
        arr = self.hostlink.transfer(arr, tag=key)    # SSD → host → HBM
        self._bump("ssd_hits")
        entry.nbytes = arr.nbytes
        self._promote_to_host(entry, arr)
        self._touch(key)
        return arr

    def _model_fetch_time(self, entry: _Entry) -> float:
        t = self.hostlink.model_time(entry.nbytes)
        if entry.tier == TIER_SSD:
            t += self.ssdlink.model_time(entry.nbytes)
        return t

    def _promote_to_host(self, entry: _Entry, arr: np.ndarray) -> None:
        """A tier-2 hit earns the block a tier-1 slot (keeps the SSD copy —
        it is free persistence for the next failure).  Stays SSD-only when
        no host room can be made."""
        if not self._make_host_room(entry):
            return
        entry.tier = TIER_HOST
        key = entry.key
        self._submit(lambda: self.host.put(key, arr),
                     tag=f"tier-promote-{key}")

    # ------------------------------------------------------------------
    # prefix cache (cross-request reuse)
    # ------------------------------------------------------------------
    @staticmethod
    def prefix_key(h: int) -> str:
        return f"pfx/{h}"

    def has_prefix(self, h: int) -> bool:
        return self.prefix_key(h) in self._entries

    def prefix_chain_len(self, hashes: Sequence[int]) -> int:
        """Longest leading run of `hashes` held by the hierarchy."""
        n = 0
        for h in hashes:
            if not self.has_prefix(h):
                break
            n += 1
        return n

    def cache_prefix_block(self, h: int, arrays: Dict[str, np.ndarray]) -> bool:
        """Write-behind demote of one FULL prompt block keyed by its chain
        hash (called when its sequence retires).  Dedups by content."""
        key = self.prefix_key(h)
        if key in self._entries:
            self._touch(key)
            return False
        packed = self._pack(arrays)
        entry = _Entry(key, "pfx", -1, False, packed.nbytes)  # tier set by admit
        self._entries[key] = entry
        self._bump("demotions")
        self._admit_host(entry, packed)
        return True

    def fetch_prefix_chain(self, hashes: Sequence[int]
                           ) -> Dict[int, Dict[str, np.ndarray]]:
        """Promote a chain of prefix blocks for installation into the pool.

        The first block's transfer is an exposed stall; the rest of the chain
        is prefetched behind it (and behind the suffix compute), so only the
        head latency lands on the critical path (modeled accounting)."""
        if not hashes:
            return {}
        if tracing.active():
            # chain identity: head hash + length pins WHICH cached prefix
            # this request adopted
            tracing.event("tier.adopt", chain=f"{hashes[0]:x}",
                          blocks=len(hashes))
        self._sync()
        keys = [self.prefix_key(h) for h in hashes]
        self._pinned.update(keys)        # mid-chain evictions must skip us
        try:
            out: Dict[int, Dict[str, np.ndarray]] = {}
            for i, h in enumerate(hashes):
                entry = self._entries[self.prefix_key(h)]
                t = self._model_fetch_time(entry)
                self._bump("stall_model_s" if i == 0 else "prefetch_model_s", t)
                out[h] = self._unpack(self._read(entry))
                self._bump("prefix_promotions")
            return out
        finally:
            self._pinned.difference_update(keys)

    # ------------------------------------------------------------------
    # swap path (preemption / restore through the hierarchy)
    # ------------------------------------------------------------------
    @staticmethod
    def swap_key(seq: int, j: int) -> str:
        return f"tswap/seq{seq}/blk{j}"

    def _swap_entries(self, seq: int) -> List[_Entry]:
        return sorted((e for e in self._entries.values()
                       if e.kind == "swap" and e.seq == seq),
                      key=lambda e: e.j)

    def swap_out_blocks(self, seq: int,
                        blocks: Dict[int, Dict[str, np.ndarray]]) -> None:
        """Offload the given (dirty) blocks of `seq` down-tier, write-behind.
        Re-offloading a block refreshes whatever copies the tiers hold."""
        for j, arrays in sorted(blocks.items()):
            key = self.swap_key(seq, j)
            packed = self._pack(arrays)
            entry = self._entries.get(key)
            if entry is None:
                entry = _Entry(key, "swap", -1, False, packed.nbytes,
                               seq=seq, j=j)  # tier set by admit
                self._entries[key] = entry
            else:
                self._touch(key)
                if entry.on_ssd:            # stale disk copy: retire it
                    self._submit(lambda k2=key: self.ssd.delete(k2),
                                 tag=f"tier-stale-{key}")
                    entry.on_ssd = False
            entry.nbytes = packed.nbytes
            self._bump("swap_out_blocks")
            self._admit_host(entry, packed)

    def swap_in_blocks(self, seq: int) -> Dict[int, Dict[str, np.ndarray]]:
        """Bring every held block of `seq` back for installation: the lowest
        tier holding each block serves it; blocks past the first are
        prefetched behind the head fetch.  Entries stay (clean blocks need
        not be re-written on the next offload)."""
        self._sync()
        entries = self._swap_entries(seq)
        keys = [e.key for e in entries]
        self._pinned.update(keys)
        try:
            out: Dict[int, Dict[str, np.ndarray]] = {}
            for i, entry in enumerate(entries):
                t = self._model_fetch_time(entry)
                self._bump("stall_model_s" if i == 0 else "prefetch_model_s", t)
                out[entry.j] = self._unpack(self._read(entry))
                self._bump("swap_in_blocks")
            return out
        finally:
            self._pinned.difference_update(keys)

    def restore_swap_from_ssd(self, seq: int, keep: int
                              ) -> Optional[Dict[int, Dict[str, np.ndarray]]]:
        """Failure recovery: serve `seq`'s first `keep` blocks from the
        persistent tier, or None if disk does not hold the full chain
        (the caller then falls back to the replication ring)."""
        self._sync()
        present = {e.j: e for e in self._swap_entries(seq) if e.on_ssd}
        if any(j not in present for j in range(keep)):
            return None
        keys = [present[j].key for j in range(keep)]
        self._pinned.update(keys)
        try:
            out: Dict[int, Dict[str, np.ndarray]] = {}
            for i in range(keep):
                entry = present[i]
                self._bump("stall_model_s" if i == 0 else "prefetch_model_s",
                           self._model_fetch_time(entry))
                out[i] = self._unpack(self._read(entry))
            self._bump("ssd_restores")
            return out
        finally:
            self._pinned.difference_update(keys)

    def drop_seq(self, seq: int) -> None:
        """Retire a finished sequence's swap entries from every tier."""
        for entry in self._swap_entries(seq):
            self._drop(entry, evicted=False)

    # ------------------------------------------------------------------
    # failure / recovery
    # ------------------------------------------------------------------
    def on_host_failure(self) -> None:
        """The worker died: tier 1 (its RAM) dies with it; tier 2 is disk and
        survives.  Entries whose only copy was host-resident are lost.  An
        ``on_ssd`` claim is only trusted if the bytes actually reached disk —
        a spill whose write died with the worker must not leave an index
        entry pointing at nothing."""
        self.host.clear()
        self._pending.clear()            # in-flight write-behinds died too
        for key, entry in list(self._entries.items()):
            if entry.on_ssd and key in self.ssd:
                entry.tier = TIER_SSD
            else:
                del self._entries[key]
                self._bump("lost_with_host")

    def reattach(self) -> int:
        """Rebuild the index from the self-describing SSD keys (fresh worker
        pointed at a dead predecessor's disk).  Returns entries recovered."""
        n = 0
        for key in self.ssd.keys():
            if key in self._entries:
                continue
            nbytes = self.ssd.size(key)    # model restores at their true cost
            if key.startswith("pfx/"):
                self._entries[key] = _Entry(key, "pfx", TIER_SSD, True, nbytes)
            elif key.startswith("tswap/seq"):
                body = key[len("tswap/seq"):]          # "<seq>/blk<j>"
                seq_s, blk_s = body.split("/blk")
                self._entries[key] = _Entry(key, "swap", TIER_SSD, True, nbytes,
                                            seq=int(seq_s), j=int(blk_s))
            else:
                continue
            n += 1
        self._bump("reattached", n)
        return n

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        out = dict(self._stats)
        out["host_blocks"] = self._host_blocks()
        out["ssd_blocks"] = self._ssd_blocks()
        out["prefix_entries"] = sum(1 for e in self._entries.values()
                                    if e.kind == "pfx")
        return out
