"""Decode-state (KV cache + SSM state) structures.

The decode state is a nested dict of arrays so that name-based sharding rules
and DéjàVuLib streaming can address leaves by path.  Layouts:

dense / vlm        {"kv": {"k": [L,B,S,Hkv,Dh], "v": ...}}
encdec             {"kv": ..., "cross": {"k": [L,B,Ssrc,Hkv,Dh], "v": ...}}
ssm (mamba2)       {"conv": [L,B,K-1,conv_dim], "ssd": [L,B,nh,hd,N]}
hybrid (hymba)     {"kv_swa":  {"k": [Lswa,B,M+W,Hkv,Dh], "v": ...},
                    "kv_full": {"k": [Lfull,B,S,Hkv,Dh], "v": ...},
                    "swa_pos": [M+W] int32 (absolute position per slot, -1=empty),
                    "conv": [L,B,K-1,conv_dim], "ssd": [L,B,nh,hd,N]}

The paper's "KV cache" generalizes to this *decode state* for attention-free
and hybrid families (DESIGN.md §Arch-applicability): everything here is what
must be swapped / streamed / replicated to resume generation.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

Shape = Tuple[int, ...]


def _conv_dim(cfg: ArchConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def decode_state_shapes(cfg: ArchConfig, batch: int, seq_len: int) -> Dict:
    """Nested dict of (shape, dtype_str) describing the decode state."""
    d = {}
    dt = cfg.dtype
    L, hkv, dh = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.family in ("dense", "moe", "vlm"):
        d["kv"] = {"k": ((L, batch, seq_len, hkv, dh), dt),
                   "v": ((L, batch, seq_len, hkv, dh), dt)}
    elif cfg.family == "encdec":
        ssrc = min(cfg.max_source_len, seq_len)
        d["kv"] = {"k": ((L, batch, seq_len, hkv, dh), dt),
                   "v": ((L, batch, seq_len, hkv, dh), dt)}
        d["cross"] = {"k": ((L, batch, ssrc, hkv, dh), dt),
                      "v": ((L, batch, ssrc, hkv, dh), dt)}
    elif cfg.family == "ssm":
        d["conv"] = ((L, batch, cfg.ssm_conv - 1, _conv_dim(cfg)), dt)
        d["ssd"] = ((L, batch, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state), "float32")
    elif cfg.family == "hybrid":
        n_full = len(cfg.full_attn_layers)
        n_swa = L - n_full
        w = cfg.num_meta_tokens + min(cfg.sliding_window, seq_len + cfg.num_meta_tokens)
        d["kv_swa"] = {"k": ((n_swa, batch, w, hkv, dh), dt),
                       "v": ((n_swa, batch, w, hkv, dh), dt)}
        full_len = seq_len + cfg.num_meta_tokens
        d["kv_full"] = {"k": ((n_full, batch, full_len, hkv, dh), dt),
                        "v": ((n_full, batch, full_len, hkv, dh), dt)}
        d["swa_pos"] = ((w,), "int32")
        d["conv"] = ((L, batch, cfg.ssm_conv - 1, _conv_dim(cfg)), dt)
        d["ssd"] = ((L, batch, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state), "float32")
    else:
        raise ValueError(cfg.family)
    return d


def _map_shapes(shapes, fn):
    if isinstance(shapes, dict):
        return {k: _map_shapes(v, fn) for k, v in shapes.items()}
    shape, dtype = shapes
    return fn(shape, dtype)


def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int):
    shapes = decode_state_shapes(cfg, batch, seq_len)

    def mk(shape, dtype):
        if dtype == "int32":
            return jnp.full(shape, -1, jnp.int32)
        return jnp.zeros(shape, jnp.dtype(dtype))

    return _map_shapes(shapes, mk)


def decode_state_specs(cfg: ArchConfig, batch: int, seq_len: int):
    """ShapeDtypeStruct stand-ins (no allocation) for dry-run lowering."""
    shapes = decode_state_shapes(cfg, batch, seq_len)
    return _map_shapes(shapes, lambda s, dt: jax.ShapeDtypeStruct(s, jnp.dtype(dt)))


def state_bytes(state) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(state))
