"""Paged KV-cache block pool (vLLM-style) under DéjàVu streaming.

The decode state of every live sequence is stored in fixed-size *blocks* of
``block_size`` token slots drawn from one shared pool, instead of one
contiguous per-microbatch cache sized ``prompt + max_new``:

  ``BlockPool``      control plane — ref-counted blocks, per-sequence block
                     tables, alloc/append/free, prefix-sharing (hash-chain
                     over full prompt blocks, copy-on-write on divergence),
                     and defragmentation (compaction to the lowest ids);
  ``PagedKVCache``   data plane — the actual page arrays for one pipeline
                     stage ``[num_blocks, Lstage, block_size, Hkv, Dh]`` plus
                     gather (blocks -> dense cache for the decode kernel) and
                     scatter (dense window -> blocks) helpers.

Blocks are also DéjàVu's streaming unit: swapping, ring replication, and
recovery (see `repro.core.worker` / `repro.core.cluster`) move individual
live blocks through DéjàVuLib instead of whole padded caches, so the bytes
on the wire track actual occupancy.

The pool is only tier 0 of the KV-cache hierarchy: `repro.kvcache.tiers`
(`KVTierManager`) extends it with host-RAM and SSD tiers — cold blocks are
demoted down-tier as write-behind, preempted sequences swap to host instead
of being dropped, and the prefix hashes published here persist across
requests, so `adopt_prefix` can rebuild a new sequence's prompt prefix from
blocks streamed back out of ANY tier instead of re-prefilling them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


class PoolExhausted(MemoryError):
    """No free block to satisfy an alloc/append — callers preempt or queue."""


@dataclass
class Block:
    bid: int
    ref: int = 0
    # content hash (prefix chain) — only set for FULL immutable prompt blocks
    hash: Optional[int] = None


def blocks_for(num_tokens: int, block_size: int) -> int:
    """Blocks needed to hold `num_tokens` token slots."""
    return -(-max(num_tokens, 0) // block_size)


class BlockPool:
    """Ref-counted fixed-size block allocator with per-sequence block tables.

    Invariants (property-tested in tests/test_paged_kv.py):
      * a block id is on the free list XOR referenced by >= 1 table;
      * sum of table multiplicities of a block == its ref count;
      * after all sequences are freed, every block is free again.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.blocks = [Block(i) for i in range(num_blocks)]
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))  # pop() -> lowest id
        self.tables: Dict[int, List[int]] = {}       # seq -> block ids (logical order)
        self.seq_lens: Dict[int, int] = {}           # seq -> live token count
        self._hash_index: Dict[int, int] = {}        # prefix hash -> bid
        self.peak_used_blocks = 0

    # --- accounting ----------------------------------------------------
    def num_free(self) -> int:
        return len(self._free)

    def num_used(self) -> int:
        return self.num_blocks - len(self._free)

    def can_allocate(self, num_tokens: int) -> bool:
        return blocks_for(num_tokens, self.block_size) <= self.num_free()

    def _track_peak(self) -> None:
        self.peak_used_blocks = max(self.peak_used_blocks, self.num_used())

    # --- alloc / append / free -----------------------------------------
    def append_needs_block(self, seq: int) -> bool:
        """Would `append(seq, 1)` consume a free block?  (New block at a
        block boundary, or copy-on-write off a shared tail block.)"""
        cur = self.seq_lens[seq]
        table = self.tables[seq]
        if cur % self.block_size == 0 or not table:
            return True
        return self.blocks[table[-1]].ref > 1

    def _take_block(self) -> int:
        if not self._free:
            raise PoolExhausted("block pool exhausted")
        bid = self._free.pop()
        blk = self.blocks[bid]
        assert blk.ref == 0
        blk.ref = 1
        blk.hash = None
        return bid

    def _drop_ref(self, bid: int) -> None:
        blk = self.blocks[bid]
        blk.ref -= 1
        assert blk.ref >= 0
        if blk.ref == 0:
            if blk.hash is not None:
                self._hash_index.pop(blk.hash, None)
            blk.hash = None
            self._free.append(bid)

    @staticmethod
    def chain_hashes(token_ids: Sequence[int], block_size: int) -> List[int]:
        """Prefix hash chain over the FULL blocks of a token sequence."""
        hashes, prev = [], 0
        n_full = len(token_ids) // block_size
        for j in range(n_full):
            chunk = tuple(int(t) for t in token_ids[j * block_size:(j + 1) * block_size])
            prev = hash((prev, chunk))
            hashes.append(prev)
        return hashes

    def allocate(self, seq: int, num_tokens: int,
                 token_ids: Optional[Sequence[int]] = None,
                 hashes: Optional[Sequence[int]] = None,
                 publish: bool = True) -> Tuple[List[int], List[int]]:
        """Allocate a table for `seq` holding `num_tokens` live tokens.

        With `token_ids` (the prompt) — or a precomputed prefix-hash chain
        `hashes` (recovery/restore, where the prompt is no longer at hand) —
        full blocks whose prefix hash matches a live block are SHARED (ref++)
        instead of newly allocated.  Returns ``(table, fresh)`` where `fresh`
        lists the logical block indices the caller must actually write
        (shared ones already hold the data).

        ``publish=False`` still SHARES matching live blocks but does not
        publish the fresh blocks' hashes: chunked prefill writes pages over
        several passes, so it publishes each block via `publish_hashes` only
        once the pages actually hold the data — a concurrent allocate/adopt
        must never share unwritten pages.
        """
        assert seq not in self.tables, f"seq {seq} already allocated"
        n = blocks_for(num_tokens, self.block_size)
        if hashes is None:
            hashes = (self.chain_hashes(token_ids, self.block_size)
                      if token_ids is not None else [])
        else:
            hashes = list(hashes)
        # pre-flight so a mid-allocation PoolExhausted can't leak blocks
        need = sum(1 for j in range(n)
                   if not (j < len(hashes) and hashes[j] in self._hash_index))
        if need > self.num_free():
            raise PoolExhausted(
                f"need {need} blocks for seq {seq}, {self.num_free()} free")
        table: List[int] = []
        fresh: List[int] = []
        for j in range(n):
            h = hashes[j] if j < len(hashes) else None
            if h is not None and h in self._hash_index:
                bid = self._hash_index[h]
                self.blocks[bid].ref += 1
                table.append(bid)
                continue
            bid = self._take_block()
            if h is not None and publish:
                self.blocks[bid].hash = h
                self._hash_index[h] = bid
            table.append(bid)
            fresh.append(j)
        self.tables[seq] = table
        self.seq_lens[seq] = num_tokens
        self._track_peak()
        return table, fresh

    def publish_hashes(self, seq: int, hashes: Sequence[int]) -> int:
        """Publish prefix-chain hashes for the LEADING blocks of `seq` (one
        hash per logical block, starting at block 0).  Chunked prefill calls
        this as each block's pages complete, pairing with
        ``allocate(..., publish=False)``.  Blocks already hashed (shared) and
        hashes already in the index are skipped.  Returns #published."""
        table = self.tables[seq]
        n = 0
        for j, h in enumerate(hashes):
            if j >= len(table):
                break
            blk = self.blocks[table[j]]
            if blk.hash is None and h not in self._hash_index:
                blk.hash = h
                self._hash_index[h] = table[j]
                n += 1
        return n

    def has_hash(self, h: int) -> bool:
        """Is a live block holding this prefix-chain hash resident (tier 0)?"""
        return h in self._hash_index

    def adopt_prefix(self, seq: int, hashes: Sequence[int],
                     num_tokens: int) -> Tuple[List[int], List[Tuple[int, int]]]:
        """Build `seq`'s table from an already-materialised prefix chain
        (cross-request reuse: the bytes come from a co-resident shared block
        or are promoted out of a lower tier by `KVTierManager`).

        Each hash either refs the live block holding it or takes a fresh
        block and publishes the hash.  Returns ``(table, fills)`` where
        `fills` lists ``(hash, bid)`` pairs whose pages the caller must
        install.  Raises PoolExhausted BEFORE any mutation."""
        assert seq not in self.tables, f"seq {seq} already allocated"
        assert num_tokens <= len(hashes) * self.block_size
        need = sum(1 for h in hashes if h not in self._hash_index)
        if need > self.num_free():
            raise PoolExhausted(
                f"need {need} blocks to adopt prefix for seq {seq}, "
                f"{self.num_free()} free")
        table: List[int] = []
        fills: List[Tuple[int, int]] = []
        for h in hashes:
            bid = self._hash_index.get(h)
            if bid is None:
                bid = self._take_block()
                self.blocks[bid].hash = h
                self._hash_index[h] = bid
                fills.append((h, bid))
            else:
                self.blocks[bid].ref += 1
            table.append(bid)
        self.tables[seq] = table
        self.seq_lens[seq] = num_tokens
        self._track_peak()
        return table, fills

    def append(self, seq: int, n: int = 1) -> List[Tuple[int, int]]:
        """Grow `seq` by `n` token slots.  Returns copy-on-write directives
        ``[(old_bid, new_bid), ...]`` — the caller must copy page contents of
        `old_bid` into `new_bid` (a shared last block diverges on write)."""
        table = self.tables[seq]
        cur = self.seq_lens[seq]
        # pre-flight (atomicity): new blocks at boundary crossings + at most
        # one copy-on-write when the first slot lands inside a shared block
        need = blocks_for(cur + n, self.block_size) - len(table)
        if table and cur % self.block_size != 0 and \
                self.blocks[table[-1]].ref > 1:
            need += 1
        if need > self.num_free():
            raise PoolExhausted(
                f"need {need} blocks to append to seq {seq}, "
                f"{self.num_free()} free")
        cow: List[Tuple[int, int]] = []
        for _ in range(n):
            if cur % self.block_size == 0 or not table:
                table.append(self._take_block())
            else:
                last = self.blocks[table[-1]]
                if last.ref > 1:                       # diverging from a shared block
                    new_bid = self._take_block()
                    cow.append((table[-1], new_bid))
                    self._drop_ref(table[-1])
                    table[-1] = new_bid
                elif last.hash is not None:
                    # uniquely owned but published for sharing: unpublish, the
                    # block is about to be mutated past the hashed prefix
                    self._hash_index.pop(last.hash, None)
                    last.hash = None
            cur += 1
        self.seq_lens[seq] = cur
        self._track_peak()
        return cow

    def truncate(self, seq: int, num_tokens: int) -> List[int]:
        """Roll `seq` back to `num_tokens` live tokens (failure-recovery
        rollback), freeing now-empty tail blocks.  Returns freed bids."""
        table = self.tables[seq]
        keep = blocks_for(max(num_tokens, 1), self.block_size)
        freed = []
        while len(table) > keep:
            bid = table.pop()
            self._drop_ref(bid)
            freed.append(bid)
        self.seq_lens[seq] = num_tokens
        return freed

    def free_seq(self, seq: int) -> None:
        for bid in self.tables.pop(seq):
            self._drop_ref(bid)
        del self.seq_lens[seq]

    def block_span(self, seq: int) -> Iterator[Tuple[int, int, int, int]]:
        """Yield ``(logical_idx, bid, t0, t1)`` for every live block of `seq`
        (t0/t1 = global token range covered; t1 clipped to the live length)."""
        n = self.seq_lens[seq]
        for j, bid in enumerate(self.tables[seq]):
            t0 = j * self.block_size
            t1 = min(t0 + self.block_size, n)
            if t1 <= t0:
                return
            yield j, bid, t0, t1

    # --- defragmentation ------------------------------------------------
    def defrag(self) -> Dict[int, int]:
        """Compact live blocks onto the lowest ids (so a pool shrink / a
        contiguous DMA window is possible).  Returns {old_bid: new_bid};
        the data plane must apply the same moves to its pages."""
        live = sorted({bid for t in self.tables.values() for bid in t})
        moves: Dict[int, int] = {}
        target = 0
        for bid in live:
            if bid != target:
                moves[bid] = target
                src, dst = self.blocks[bid], self.blocks[target]
                dst.ref, dst.hash = src.ref, src.hash
                src.ref, src.hash = 0, None
                if dst.hash is not None:
                    self._hash_index[dst.hash] = target
            target += 1
        if moves:
            for table in self.tables.values():
                for i, bid in enumerate(table):
                    table[i] = moves.get(bid, bid)
            self._free = list(range(self.num_blocks - 1, target - 1, -1))
        return moves


@dataclass
class PagedKVCache:
    """Data plane for one pipeline stage: pages ``[N, Lstage, bs, Hkv, Dh]``.

    Pages are host-of-truth numpy (this repro computes in interpret mode; on
    a real TPU the same layout backs the `paged_decode_attention` kernel, and
    these helpers become device gathers)."""
    pool: BlockPool
    layers: int
    num_kv_heads: int
    head_dim: int
    dtype: str = "float32"
    k: np.ndarray = field(init=False)
    v: np.ndarray = field(init=False)

    def __post_init__(self):
        shape = (self.pool.num_blocks, self.layers, self.pool.block_size,
                 self.num_kv_heads, self.head_dim)
        self.k = np.zeros(shape, np.dtype(self.dtype))
        self.v = np.zeros(shape, np.dtype(self.dtype))

    @property
    def block_bytes(self) -> int:
        return 2 * self.layers * self.pool.block_size * self.num_kv_heads \
            * self.head_dim * np.dtype(self.dtype).itemsize

    def used_bytes(self) -> int:
        return self.pool.num_used() * self.block_bytes

    # --- dense <-> paged ------------------------------------------------
    def write_window(self, seq: int, kv: Dict[str, np.ndarray], t0: int) -> List[int]:
        """Scatter a dense window ``[Lstage, W, H, D]`` (tokens t0..t0+W) of
        `seq` into its pages.  Returns the bids touched (the streaming
        delta)."""
        touched = []
        for leaf, win in kv.items():
            pages = self.k if leaf == "k" else self.v
            w = win.shape[1]
            for j, bid, b0, b1 in self.pool.block_span(seq):
                lo, hi = max(b0, t0), min(b1, t0 + w)
                if lo >= hi:
                    continue
                pages[bid, :, lo - b0:hi - b0] = win[:, lo - t0:hi - t0]
                if leaf == "k":
                    touched.append(bid)
        return touched

    def gather_dense(self, seq: int, pad_to: int) -> Dict[str, np.ndarray]:
        """Assemble `seq`'s live tokens into a dense ``[Lstage, 1, pad_to,
        H, D]`` cache (the layout `stage_decode` consumes)."""
        out = {}
        for leaf, pages in (("k", self.k), ("v", self.v)):
            dense = np.zeros((self.layers, 1, pad_to, self.num_kv_heads,
                              self.head_dim), pages.dtype)
            for j, bid, t0, t1 in self.pool.block_span(seq):
                dense[:, 0, t0:t1] = pages[bid, :, :t1 - t0]
            out[leaf] = dense
        return out

    def copy_block(self, src_bid: int, dst_bid: int) -> None:
        """Apply a copy-on-write / defrag move to the pages."""
        self.k[dst_bid] = self.k[src_bid]
        self.v[dst_bid] = self.v[src_bid]

    def apply_cow(self, cow: Sequence[Tuple[int, int]]) -> None:
        for old, new in cow:
            self.copy_block(old, new)

    def apply_defrag(self, moves: Dict[int, int]) -> None:
        for old, new in sorted(moves.items(), key=lambda kv: kv[1]):
            self.copy_block(old, new)

    def block_arrays(self, bid: int, width: Optional[int] = None
                     ) -> Dict[str, np.ndarray]:
        """One block's pages (optionally only the first `width` token slots)
        — the unit DéjàVuLib streams for swap / replication / recovery."""
        w = self.pool.block_size if width is None else width
        return {"k": self.k[bid, :, :w].copy(), "v": self.v[bid, :, :w].copy()}

    def install_block(self, bid: int, arrays: Dict[str, np.ndarray]) -> None:
        for leaf, arr in arrays.items():
            pages = self.k if leaf == "k" else self.v
            pages[bid, :, :arr.shape[1]] = arr
