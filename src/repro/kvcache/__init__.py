from repro.kvcache.cache import (
    decode_state_shapes,
    init_decode_state,
    decode_state_specs,
    state_bytes,
)

__all__ = ["decode_state_shapes", "init_decode_state", "decode_state_specs", "state_bytes"]
