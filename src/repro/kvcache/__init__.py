from repro.kvcache.cache import (
    decode_state_shapes,
    decode_state_specs,
    init_decode_state,
    state_bytes,
)
from repro.kvcache.paged import (Block, BlockPool, PagedKVCache, PoolExhausted,
                                 blocks_for)
from repro.kvcache.tiers import (TIER_HBM, TIER_HOST, TIER_SSD, KVTierManager,
                                 TierConfig)

__all__ = ["decode_state_shapes", "init_decode_state", "decode_state_specs",
           "state_bytes", "Block", "BlockPool", "PagedKVCache", "PoolExhausted",
           "blocks_for", "KVTierManager", "TierConfig", "TIER_HBM",
           "TIER_HOST", "TIER_SSD"]
