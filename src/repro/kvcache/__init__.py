from repro.kvcache.cache import (
    decode_state_shapes,
    init_decode_state,
    decode_state_specs,
    state_bytes,
)
from repro.kvcache.paged import (Block, BlockPool, PagedKVCache, PoolExhausted,
                                 blocks_for)

__all__ = ["decode_state_shapes", "init_decode_state", "decode_state_specs",
           "state_bytes", "Block", "BlockPool", "PagedKVCache", "PoolExhausted",
           "blocks_for"]
