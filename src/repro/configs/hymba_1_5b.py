"""Hymba-1.5B — hybrid parallel attention + Mamba heads. [arXiv:2411.13676; hf]

Each layer runs attention heads and SSM heads in PARALLEL on the same input
and mean-fuses the normalized outputs.  Most layers use sliding-window
attention (window=1024); layers (first, middle, last) use global attention.
128 learnable meta tokens are prepended to the context.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    sliding_window=1024,
    num_meta_tokens=128,
    full_attn_layers=(0, 15, 31),
    activation="silu",
    norm="rmsnorm",
    pos_emb="rope",
    source="arXiv:2411.13676; hf",
)
