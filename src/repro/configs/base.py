"""Architecture + shape configuration system.

Every selectable architecture (``--arch <id>``) is an ``ArchConfig`` instance
registered in :mod:`repro.configs.registry`.  Shapes (the assigned input-shape
set) are ``ShapeConfig`` instances.  ``reduced()`` produces the smoke-test
scale of the same family (tiny widths, few layers/experts) used by unit tests;
the FULL configs are exercised only through the compile-only dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default: d_model // num_heads
    activation: str = "silu"                 # silu | gelu | relu2
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    pos_emb: str = "rope"                    # rope | learned | alibi | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    # --- hybrid (Hymba) ---
    sliding_window: int = 0                  # 0 = full attention everywhere
    num_meta_tokens: int = 0
    full_attn_layers: Tuple[int, ...] = ()
    # --- enc-dec ---
    num_encoder_layers: int = 0
    cross_attention: bool = False
    max_source_len: int = 4096
    # --- VLM ---
    num_patches: int = 0                     # stub patch-embedding positions
    # --- paged KV cache (serving) ---
    kv_block_size: int = 8                   # tokens per KV block (DMA-aligned)
    kv_pool_blocks: int = 0                  # pool size per stage; 0 = auto
    # Q tokens per chunked-prefill pipeline pass (paged serving).  Prompts and
    # adopted-prefix suffixes longer than this are split into chunks that the
    # continuous-batching scheduler interleaves with decode steps, bounding
    # how long a long prompt stalls in-flight decodes.  0 disables chunking
    # (cold prompts prefill in one pass, adopted suffixes run token-at-a-time).
    prefill_chunk_tokens: int = 64
    # Fused batched rounds (continuous batching): ONE pipeline pass decodes
    # every live sequence per round (ragged per-sequence lengths over
    # per-sequence block tables) and one pass packs all in-flight prefill
    # chunks, instead of one pass per sequence per round.  ON by default —
    # the batched mask/bias path is exact for every dense/moe attention
    # variant (full-causal, ALiBi, sliding-window+meta); unsupported
    # families (ssm/hybrid/encdec/vlm) fall back per-sequence via the
    # cluster's `fused_ok` gate.  Set False (or pass fused_rounds=False to
    # the engine) to force the per-sequence oracle path, which fused mode
    # is property-tested against.
    fused_rounds: bool = True
    # --- misc ---
    dtype: str = "bfloat16"
    max_seq_len: int = 524288
    source: str = ""                         # provenance note

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def context_overhead(self) -> int:
        """Non-text context slots prepended to the prompt (patches/meta)."""
        return self.num_patches + self.num_meta_tokens

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test scale config of the same family (CPU-runnable)."""
        kw = dict(
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            max_seq_len=256,
            max_source_len=32,
        )
        if self.is_moe:
            kw.update(num_experts=4, experts_per_token=2, d_ff=32)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=8, ssm_head_dim=16, ssm_expand=2)
        if self.family == "hybrid":
            kw.update(sliding_window=16, num_meta_tokens=4, full_attn_layers=(0,))
        if self.family == "encdec":
            kw.update(num_encoder_layers=2)
        if self.family == "vlm":
            kw.update(num_patches=8)
        return replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count N (analytic)."""
        d, hd = self.d_model, self.resolved_head_dim
        per_layer = 0
        if self.family != "ssm":
            # attention: q,k,v,o projections
            per_layer += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            # in_proj (z,x,B,C,dt) + out_proj + conv + A,D
            g = self.ssm_ngroups
            per_layer += d * (2 * di + 2 * g * self.ssm_state + self.ssm_nheads)
            per_layer += di * d
            per_layer += self.ssm_conv * (di + 2 * g * self.ssm_state)
            per_layer += 2 * self.ssm_nheads
        if self.is_moe:
            per_layer += self.num_experts * 3 * d * self.d_ff  # gated experts
            per_layer += d * self.num_experts                  # router
        elif self.d_ff:
            n_mats = 3 if self.activation == "silu" else 2     # gated vs plain
            per_layer += n_mats * d * self.d_ff
        per_layer += 2 * d                                     # norms
        total = self.num_layers * per_layer
        if self.cross_attention:
            total += self.num_layers * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + d)
        if self.num_encoder_layers:
            enc_layer = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            n_mats = 3 if self.activation == "silu" else 2
            enc_layer += n_mats * d * self.d_ff + 2 * d
            total += self.num_encoder_layers * enc_layer
        total += self.vocab_size * d                           # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                       # lm head
        if self.num_meta_tokens:
            total += self.num_meta_tokens * d
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        total = self.param_count()
        expert_params = self.num_layers * self.num_experts * 3 * self.d_model * self.d_ff
        active_expert = self.num_layers * self.experts_per_token * 3 * self.d_model * self.d_ff
        return total - expert_params + active_expert

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Decode-state bytes appended per generated token (per request)."""
        if self.family == "ssm":
            return 0  # fixed-size state, nothing appended
        n_attn_layers = self.num_layers
        return n_attn_layers * 2 * self.kv_dim * dtype_bytes

    def decode_state_bytes(self, seq_len: int, dtype_bytes: int = 2) -> int:
        """Total decode-state footprint for one request at context seq_len."""
        total = 0
        if self.family == "ssm":
            total += self.num_layers * self.ssm_nheads * self.ssm_head_dim * self.ssm_state * 4
            return total
        if self.family == "hybrid":
            # SSM state + windowed KV on SWA layers + full KV on global layers
            total += self.num_layers * self.ssm_nheads * self.ssm_head_dim * self.ssm_state * 4
            n_full = len(self.full_attn_layers)
            n_swa = self.num_layers - n_full
            w = min(self.sliding_window or seq_len, seq_len)
            total += n_swa * 2 * self.kv_dim * w * dtype_bytes
            total += n_full * 2 * self.kv_dim * seq_len * dtype_bytes
            return total
        total += self.num_layers * 2 * self.kv_dim * seq_len * dtype_bytes
        if self.cross_attention:
            total += self.num_layers * 2 * self.kv_dim * min(self.max_source_len, seq_len) * dtype_bytes
        return total

    def paged_state_bytes(self, live_tokens: int, dtype_bytes: int = 2) -> int:
        """Decode-state footprint under the paged pool: `live_tokens` rounded
        up to whole KV blocks (vs `decode_state_bytes`, which reserves the
        full prompt+max_new window for the request's entire lifetime)."""
        bs = max(self.kv_block_size, 1)
        rounded = -(-live_tokens // bs) * bs
        return self.decode_state_bytes(rounded, dtype_bytes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supports_shape(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(supported, reason-if-not).  long_500k needs sub-quadratic decode state."""
    if shape.name == "long_500k" and arch.family not in ("ssm", "hybrid"):
        return False, (
            "long_500k requires sub-quadratic attention; %s is a pure "
            "full-attention arch (512k dense KV cache) — skipped per assignment, "
            "see DESIGN.md §Arch-applicability" % arch.name
        )
    return True, ""
