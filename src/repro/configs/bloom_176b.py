"""BLOOM-176B — the paper's largest evaluation model (Fig. 12b)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="bloom-176b",
    family="dense",
    num_layers=70,
    d_model=14336,
    num_heads=112,
    num_kv_heads=112,
    head_dim=128,
    d_ff=57344,
    vocab_size=250880,
    activation="gelu",
    norm="layernorm",
    pos_emb="alibi",
    max_seq_len=2048,
    source="BigScience (paper baseline)",
)
