"""Phi-3-vision-4.2B — phi3-mini backbone + CLIP frontend (STUB).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The vision tower is a STUB: ``input_specs()`` supplies precomputed patch
embeddings ``[B, num_patches, d_model]`` that are prepended to the token
embeddings; only the 32L transformer backbone is implemented.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    num_patches=576,
    activation="silu",
    norm="rmsnorm",
    pos_emb="rope",
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
)
