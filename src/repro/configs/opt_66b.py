"""OPT-66B — the paper's primary evaluation model (Fig. 12a, 14, 15)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="opt-66b",
    family="dense",
    num_layers=64,
    d_model=9216,
    num_heads=72,
    num_kv_heads=72,
    head_dim=128,
    d_ff=36864,
    vocab_size=50272,
    activation="gelu",
    norm="layernorm",
    pos_emb="learned",
    max_seq_len=2048,
    source="arXiv:2205.01068 (paper baseline)",
)
