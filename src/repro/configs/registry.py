"""Registry of all selectable architectures (``--arch <id>``)."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.configs.bloom_176b import CONFIG as _bloom
from repro.configs.gpt2_1_5b import CONFIG as _gpt2
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.nemotron_4_340b import CONFIG as _nemotron
from repro.configs.opt_66b import CONFIG as _opt66b
from repro.configs.phi_3_vision_4_2b import CONFIG as _phi3v
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3_moe
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.yi_34b import CONFIG as _yi_34b

# The 10 assigned architectures (dry-run + roofline matrix).
ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        _yi_34b, _nemotron, _smollm, _internlm2, _seamless,
        _moonshot, _qwen3_moe, _hymba, _phi3v, _mamba2,
    )
}

# The paper's own evaluation models (benchmarks/figures).
PAPER_ARCHS: dict[str, ArchConfig] = {c.name: c for c in (_opt66b, _bloom, _gpt2)}

_ALL = {**ARCHS, **PAPER_ARCHS}


def get_arch(name: str) -> ArchConfig:
    if name not in _ALL:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_ALL)}")
    return _ALL[name]


def list_archs(include_paper: bool = False) -> list[str]:
    return sorted(_ALL if include_paper else ARCHS)
