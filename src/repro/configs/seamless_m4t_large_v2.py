"""SeamlessM4T-large-v2 — enc-dec multimodal (audio) backbone. [arXiv:2308.11596; hf]

The modality frontend (speech feature extractor) is a STUB: ``input_specs()``
supplies precomputed frame embeddings ``[B, S_frames, d_model]``.  Only the
transformer backbone (24L encoder + 24L decoder with cross-attention) is
implemented, per the assignment.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,               # decoder layers
    num_encoder_layers=24,
    cross_attention=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    activation="gelu",
    norm="layernorm",
    pos_emb="learned",
    max_source_len=4096,
    max_seq_len=32768,           # decoder learned-pos table bound
    source="arXiv:2308.11596; hf",
)
