"""GPT2-1.5B — the paper's failure-recovery illustration model (Fig. 4)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gpt2-1.5b",
    family="dense",
    num_layers=48,
    d_model=1600,
    num_heads=25,
    num_kv_heads=25,
    head_dim=64,
    d_ff=6400,
    vocab_size=50257,
    activation="gelu",
    norm="layernorm",
    pos_emb="learned",
    max_seq_len=2048,
    tie_embeddings=True,
    source="paper Fig. 4 model",
)
