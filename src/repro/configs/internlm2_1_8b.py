"""InternLM2-1.8B — dense GQA LM. [arXiv:2403.17297; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    activation="silu",
    norm="rmsnorm",
    pos_emb="rope",
    source="arXiv:2403.17297; hf",
)
