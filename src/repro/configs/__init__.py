from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, supports_shape
from repro.configs.registry import ARCHS, PAPER_ARCHS, get_arch, list_archs

__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "supports_shape",
    "ARCHS", "PAPER_ARCHS", "get_arch", "list_archs",
]
