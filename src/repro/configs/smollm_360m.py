"""SmolLM-360M — llama-arch small dense GQA LM. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    activation="silu",
    norm="rmsnorm",
    pos_emb="rope",
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M; hf",
)
