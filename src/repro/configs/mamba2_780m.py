"""Mamba2-780M — attention-free SSD (state-space duality). [arXiv:2405.21060; unverified]

No KV cache exists; the decode state is a fixed-size SSD state per layer.
DéjàVu's KV streaming generalizes to SSM-state streaming for this arch
(see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                      # attn-free, no MLP block (Mamba-2 backbone)
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_ngroups=1,
    activation="silu",
    norm="rmsnorm",
    pos_emb="none",
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
