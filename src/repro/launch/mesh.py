"""Production mesh factories.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — only launch/dryrun.py sets the 512-placeholder-
device XLA flag, and only before its first jax import.
"""
from __future__ import annotations

import jax

try:                                     # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:                      # jax 0.4.x: Auto is the only behavior
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests)."""
    return _mesh((1, 1), ("data", "model"))
