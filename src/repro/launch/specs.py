"""input_specs() + step/layer functions for the compile-only dry-run.

Everything here is ShapeDtypeStruct-based (weak-type-correct, shardable, no
device allocation).  For each (arch × shape) cell we expose:

  * the MAIN step (train_step / prefill / serve_step) with full shardings —
    lowered + compiled for feasibility, memory analysis and the collective
    schedule;
  * per-layer correction functions — `jax.lax.scan` bodies are counted ONCE
    by XLA cost analysis regardless of trip count (verified empirically), so
    roofline totals are reconstructed as cost(step) + Σ (L−1)·cost(layer),
    with each layer lowered as an L=1 scan under identical shardings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import (_axes, _size, batch_shardings,
                                        param_shardings, state_shardings)
from repro.kvcache.cache import decode_state_shapes
from repro.models import build_model
from repro.training.optimizer import AdamWState
from repro.training.train import TrainConfig, make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        s_text = s - cfg.num_patches
        out = {"tokens": sds((b, s_text), "int32"),
               "patch_embeds": sds((b, cfg.num_patches, cfg.d_model), "float32")}
        tgt = s_text
    elif cfg.family == "encdec":
        ssrc = min(cfg.max_source_len, s)
        out = {"tokens": sds((b, s), "int32"),
               "src_embeds": sds((b, ssrc, cfg.d_model), "float32")}
        tgt = s
    elif cfg.family == "hybrid":
        s_text = s - cfg.num_meta_tokens       # meta tokens fill the context
        out = {"tokens": sds((b, s_text), "int32")}
        tgt = s_text
    else:
        out = {"tokens": sds((b, s), "int32")}
        tgt = s
    if shape.kind == "train":
        out["targets"] = sds((b, tgt), "int32")
        out["loss_mask"] = sds((b, tgt), "float32")
    return out


def state_specs(cfg: ArchConfig, shape: ShapeConfig):
    shapes = decode_state_shapes(cfg, shape.global_batch, shape.seq_len)

    def mk(t):
        if isinstance(t, dict):
            return {k: mk(v) for k, v in t.items()}
        sh, dt = t
        return sds(sh, dt)
    return mk(shapes)


def params_specs(cfg: ArchConfig, model) -> Dict:
    return jax.eval_shape(model.init, jax.random.key(0))


def input_specs(arch: ArchConfig, shape: ShapeConfig, model=None) -> Dict:
    """All model inputs for this cell as ShapeDtypeStructs (assignment API)."""
    model = model or build_model(arch)
    out = {"params": params_specs(arch, model)}
    if shape.kind == "train":
        out["batch"] = batch_specs(arch, shape)
        out["opt_state"] = jax.eval_shape(
            lambda p: AdamWState(jnp.zeros((), jnp.int32),
                                 jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                                 jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)),
            out["params"])
    elif shape.kind == "prefill":
        out["batch"] = batch_specs(arch, shape)
    else:  # decode
        out["state"] = state_specs(arch, shape)
        out["token"] = sds((shape.global_batch,), "int32")
        out["pos"] = sds((), "int32")
    return out


# ---------------------------------------------------------------------------
# step + layer functions per cell
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Lowerable:
    name: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: object          # pytree or None
    multiplier: float = 1.0        # applied to cost when summing the roofline
    donate: tuple = ()


def _no_shard(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def make_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
              remat: bool = True, variant: str = "baseline") -> List[Lowerable]:
    """The main step + layer-correction lowerables for one (arch × shape).

    variant="opt" switches on the hillclimbed configuration: blocked (flash-
    style) attention + explicit tensor/sequence-parallel activation
    constraints (see distributed.sharding.activation_rules)."""
    from repro.distributed.sharding import activation_rules
    from repro.models.common import set_logical_rules
    rules = activation_rules(mesh, variant, shape.kind)
    pvariant = variant
    if variant.startswith("opt") and cfg.is_moe and rules is not None:
        # MoE: the sort-based dispatch gathers/scatters over ALL tokens;
        # seq-sharded residuals and expert-sharded dispatch buffers both
        # force whole-activation regathers per layer (measured 3-4x
        # regression, EXPERIMENTS.md §Perf).  Keep the MoE block on the baseline GSPMD
        # propagation; blocked attention + head sharding still apply.
        rules = {**rules, "seq": None, "experts": None}
    if variant.startswith("opt") and shape.kind == "decode":
        # decode: the cache stays seq-sharded; GSPMD's partial-softmax over
        # the sharded seq axis IS flash-decode split-K.  Forcing head
        # sharding would re-shard the whole cache every step (measured 4x
        # regression — §Perf), so attention constraints are dropped here.
        if rules is not None:
            rules = {**rules, "heads": None, "kv_heads": None}
        if cfg.family == "ssm":
            # tiny-batch decode is weight-traffic-bound: row-shard SSM weights
            # (pure-SSM only: hymba's mixed attn+SSM layers regress — §Perf)
            pvariant = "opt-rowssm"
            if rules is not None:
                rules = {**rules, "d_inner": None, "ssm_heads": None}
        elif cfg.family == "hybrid":
            # hymba decode: every constraint combination measured worse than
            # GSPMD's own propagation (EXPERIMENTS.md §Perf) — keep the baseline config
            rules = None
            pvariant = "baseline"
    set_logical_rules(rules)
    # blocked (flash-style) attention pays off where scores would be S^2
    # (prefill/train); decode keeps the einsum split-K form
    backend = ("blocked" if variant.startswith("opt")
               and shape.kind != "decode" else "xla")
    model = build_model(cfg, backend=backend,
                        remat=(remat and shape.kind == "train"))
    p_specs = params_specs(cfg, model)
    p_sh = param_shardings(p_specs, cfg, mesh, pvariant)
    out: List[Lowerable] = []

    if shape.kind == "train":
        tstep = make_train_step(model, TrainConfig())
        b_specs = batch_specs(cfg, shape)
        b_sh = batch_shardings(b_specs, cfg, mesh)
        o_specs = input_specs(cfg, shape, model)["opt_state"]
        o_sh = param_shardings(o_specs, cfg, mesh)
        out_shapes = jax.eval_shape(tstep, p_specs, o_specs, b_specs)
        out_sh = (p_sh, o_sh, _no_shard(out_shapes[2], mesh))
        out.append(Lowerable("train_step", tstep, (p_specs, o_specs, b_specs),
                             (p_sh, o_sh, b_sh), out_sh))
    elif shape.kind == "prefill":
        b_specs = batch_specs(cfg, shape)
        b_sh = batch_shardings(b_specs, cfg, mesh)

        def prefill(params, batch):
            return model.prefill(params, batch, max_len=shape.seq_len)

        out_shapes = jax.eval_shape(prefill, p_specs, b_specs)
        logits_sh = _logits_sharding(mesh, shape, cfg.vocab_size)
        st_sh = state_shardings(out_shapes[1], cfg, mesh, shape.global_batch)
        out.append(Lowerable("prefill", prefill, (p_specs, b_specs),
                             (p_sh, b_sh), (logits_sh, st_sh, NamedSharding(mesh, P()))))
    else:  # decode / serve_step
        st_specs = state_specs(cfg, shape)
        st_sh = state_shardings(st_specs, cfg, mesh, shape.global_batch)
        tok = sds((shape.global_batch,), "int32")
        tok_sh = batch_shardings(tok, cfg, mesh)
        pos = sds((), "int32")

        def serve_step(params, state, token, p):
            return model.decode_step(params, state, token, p)

        logits_sh = _logits_sharding(mesh, shape, cfg.vocab_size)
        out.append(Lowerable("serve_step", serve_step,
                             (p_specs, st_specs, tok, pos),
                             (p_sh, st_sh, tok_sh, NamedSharding(mesh, P())),
                             (logits_sh, st_sh), donate=(1,)))

    out.extend(_layer_corrections(cfg, shape, mesh, model, p_specs, p_sh))
    return out


def _logits_sharding(mesh: Mesh, shape: ShapeConfig, vocab: int = 0):
    dp, mp = _axes(mesh)
    b = shape.global_batch
    spec = [dp if b % _size(mesh, dp) == 0 else None,
            mp if vocab % mesh.shape[mp] == 0 else None]
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# per-layer correction lowerables
# ---------------------------------------------------------------------------

def _slice1(tree, idx=0):
    return jax.tree.map(lambda a: sds((1,) + tuple(a.shape[1:]), a.dtype), tree)


def _layer_corrections(cfg, shape, mesh, model, p_specs, p_sh
                       ) -> List[Lowerable]:
    dp, mp = _axes(mesh)
    b, s = shape.global_batch, shape.seq_len
    dtype = cfg.dtype
    dp_ok = b % _size(mesh, dp) == 0
    x_spec = sds((b, s if shape.kind != "decode" else 1, cfg.d_model), dtype)
    x_sh = NamedSharding(mesh, P(dp if dp_ok else None, None, None))
    train = shape.kind == "train"
    out: List[Lowerable] = []

    def layers_sh(key="layers"):
        return jax.tree.map(lambda x: x, p_sh[key])  # same tree

    def l1(tree_key):
        return _slice1(p_specs[tree_key]), jax.tree.map(lambda s_: s_, p_sh[tree_key])

    if cfg.family in ("dense", "moe", "vlm"):
        lp1, lp_sh = l1("layers")
        if shape.kind == "decode":
            L = cfg.num_layers
            hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
            kc = sds((1, b, s, hkv, dh), dtype)
            kv_sh = state_shardings({"kv": {"k": ((1, b, s, hkv, dh), dtype)}},
                                    cfg, mesh, b)["kv"]["k"]

            def dec_layer(lp, x, kc_, vc_):
                kv_positions = jnp.arange(s, dtype=jnp.int32)

                def body(x, xs):
                    lp_, k_, v_ = xs
                    x, (k_, v_), _ = model._layer(
                        x, lp_, mode="decode", kc=k_, vc=v_,
                        kv_positions=kv_positions, pos=jnp.int32(s - 1))
                    return x, (k_, v_)
                x, _ = jax.lax.scan(body, x, (lp, kc_, vc_))
                return x

            out.append(Lowerable("layer", dec_layer, (lp1, x_spec, kc, kc),
                                 (lp_sh, x_sh, kv_sh, kv_sh), None,
                                 multiplier=cfg.num_layers - 1))
        else:
            def fwd(lp, x):
                positions = jnp.arange(x.shape[1], dtype=jnp.int32)

                def body(x, lp_):
                    x, _, _ = model._layer(x, lp_, mode="prefill",
                                           positions=positions,
                                           collect_aux=False)
                    return x, None
                if train and model.remat:
                    body = jax.checkpoint(body)
                x, _ = jax.lax.scan(body, x, lp)
                return x

            fn = (lambda lp, x: jax.grad(lambda l_, x_: jnp.sum(
                fwd(l_, x_).astype(jnp.float32)))(lp, x)) if train else fwd
            out.append(Lowerable("layer", fn, (lp1, x_spec), (lp_sh, x_sh),
                                 None, multiplier=cfg.num_layers - 1))

    elif cfg.family == "ssm":
        from repro.models import ssm as ssm_mod
        from repro.models.common import norm_apply
        lp1, lp_sh = l1("layers")
        if shape.kind == "decode":
            st = decode_state_shapes(cfg, b, s)
            conv1 = sds((1,) + st["conv"][0][1:], st["conv"][1])
            ssd1 = sds((1,) + st["ssd"][0][1:], st["ssd"][1])
            stsh = state_shardings({"conv": ((1,) + st["conv"][0][1:], st["conv"][1]),
                                    "ssd": ((1,) + st["ssd"][0][1:], st["ssd"][1])},
                                   cfg, mesh, b)

            def dec_layer(lp, x, conv, ssd_st):
                def body(x, xs):
                    lp_, c_, h_ = xs
                    hin = norm_apply(cfg.norm, x, lp_["ln"])
                    o, h_, c_ = ssm_mod.ssm_decode(hin, lp_["ssm"], cfg, h_, c_)
                    return x + o, (c_, h_)
                x, _ = jax.lax.scan(body, x, (lp, conv, ssd_st))
                return x

            out.append(Lowerable("layer", dec_layer, (lp1, x_spec, conv1, ssd1),
                                 (lp_sh, x_sh, stsh["conv"], stsh["ssd"]), None,
                                 multiplier=cfg.num_layers - 1))
        else:
            def fwd(lp, x):
                def body(x, lp_):
                    hin = norm_apply(cfg.norm, x, lp_["ln"])
                    o, _, _ = ssm_mod.ssm_prefill(hin, lp_["ssm"], cfg)
                    return x + o, None
                if train and model.remat:
                    body = jax.checkpoint(body)
                x, _ = jax.lax.scan(body, x, lp)
                return x

            fn = (lambda lp, x: jax.grad(lambda l_, x_: jnp.sum(
                fwd(l_, x_).astype(jnp.float32)))(lp, x)) if train else fwd
            out.append(Lowerable("layer", fn, (lp1, x_spec), (lp_sh, x_sh),
                                 None, multiplier=cfg.num_layers - 1))

    elif cfg.family == "hybrid":
        lp1, lp_sh = l1("layers")
        n_swa = cfg.num_layers - len(cfg.full_attn_layers)
        n_scans = sum(1 for seg in model.segs if seg[0] == "swa")
        st_len = s  # total context (meta included via shape semantics)
        if shape.kind == "decode":
            hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
            m, w = cfg.num_meta_tokens, cfg.sliding_window
            st = decode_state_shapes(cfg, b, s)
            kswa1 = sds((1,) + st["kv_swa"]["k"][0][1:], dtype)
            conv1 = sds((1,) + st["conv"][0][1:], st["conv"][1])
            ssd1 = sds((1,) + st["ssd"][0][1:], st["ssd"][1])
            stsh = state_shardings(
                {"kv_swa": {"k": ((1,) + st["kv_swa"]["k"][0][1:], dtype)},
                 "conv": ((1,) + st["conv"][0][1:], st["conv"][1]),
                 "ssd": ((1,) + st["ssd"][0][1:], st["ssd"][1])}, cfg, mesh, b)

            def swa_dec(lp, x, kc, vc, conv, ssd_st):
                swa_pos = jnp.arange(kswa1.shape[2], dtype=jnp.int32)

                def body(x, xs):
                    lp_, k_, v_, c_, h_ = xs
                    from repro.models.common import norm_apply, rmsnorm
                    from repro.models import attention as attn_mod, ssm as ssm_mod
                    from repro.models.mlp import mlp_apply
                    h = norm_apply(cfg.norm, x, lp_["ln1"])
                    a, k_, v_ = attn_mod.attention_decode(
                        h, lp_["attn"], cfg, k_, v_, swa_pos, jnp.int32(st_len - 1),
                        window=w, num_meta=m, write_index=jnp.int32(m))
                    so, h_, c_ = ssm_mod.ssm_decode(h, lp_["ssm"], cfg, h_, c_)
                    x = x + 0.5 * (rmsnorm(a, lp_["fuse_na"]) + rmsnorm(so, lp_["fuse_ns"]))
                    x = x + mlp_apply(norm_apply(cfg.norm, x, lp_["ln2"]), lp_["mlp"], cfg)
                    return x, (k_, v_, c_, h_)
                x, _ = jax.lax.scan(body, x, (lp, kc, vc, conv, ssd_st))
                return x

            out.append(Lowerable("swa_layer", swa_dec,
                                 (lp1, x_spec, kswa1, kswa1, conv1, ssd1),
                                 (lp_sh, x_sh, stsh["kv_swa"]["k"], stsh["kv_swa"]["k"],
                                  stsh["conv"], stsh["ssd"]), None,
                                 multiplier=n_swa - n_scans))
        else:
            def swa_fwd(lp, x):
                positions = jnp.arange(x.shape[1], dtype=jnp.int32)

                def body(x, lp_):
                    x, _, _, _, _ = model._layer_parallel(x, lp_, positions,
                                                          window=cfg.sliding_window)
                    return x, None
                if train and model.remat:
                    body = jax.checkpoint(body)
                x, _ = jax.lax.scan(body, x, lp)
                return x

            fn = (lambda lp, x: jax.grad(lambda l_, x_: jnp.sum(
                swa_fwd(l_, x_).astype(jnp.float32)))(lp, x)) if train else swa_fwd
            out.append(Lowerable("swa_layer", fn, (lp1, x_spec), (lp_sh, x_sh),
                                 None, multiplier=n_swa - n_scans))

    elif cfg.family == "encdec":
        from repro.models.common import norm_apply
        from repro.models import attention as attn_mod
        from repro.models.mlp import mlp_apply
        ssrc = min(cfg.max_source_len, s)
        enc1, enc_sh = l1("enc_layers")
        dec1, dec_sh = l1("dec_layers")
        xe_spec = sds((b, ssrc, cfg.d_model), dtype)
        xe_sh = x_sh
        if shape.kind == "decode":
            hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
            kc = sds((1, b, s, hkv, dh), dtype)
            ck = sds((1, b, ssrc, hkv, dh), dtype)
            kv_sh = state_shardings({"kv": {"k": ((1, b, s, hkv, dh), dtype)}},
                                    cfg, mesh, b)["kv"]["k"]
            ck_sh = state_shardings({"cross": {"k": ((1, b, ssrc, hkv, dh), dtype)}},
                                    cfg, mesh, b)["cross"]["k"]

            def dec_layer(lp, x, kc_, vc_, ck_, cv_):
                kv_positions = jnp.arange(s, dtype=jnp.int32)

                def body(x, xs):
                    lp_, k_, v_, c1, c2 = xs
                    h = norm_apply(cfg.norm, x, lp_["ln1"])
                    a, k_, v_ = attn_mod.attention_decode(
                        h, lp_["attn"], cfg, k_, v_, kv_positions,
                        jnp.int32(s - 1), rope=False)
                    x = x + a
                    h = norm_apply(cfg.norm, x, lp_["lnx"])
                    x = x + attn_mod.cross_attention(h, lp_["cross"], cfg, c1, c2)
                    x = x + mlp_apply(norm_apply(cfg.norm, x, lp_["ln2"]), lp_["mlp"], cfg)
                    return x, None
                x, _ = jax.lax.scan(body, x, (lp, kc_, vc_, ck_, cv_))
                return x

            out.append(Lowerable("dec_layer", dec_layer,
                                 (dec1, x_spec, kc, kc, ck, ck),
                                 (dec_sh, x_sh, kv_sh, kv_sh, ck_sh, ck_sh), None,
                                 multiplier=cfg.num_layers - 1))
        else:
            def enc_fwd(lp, x):
                def body(x, lp_):
                    h = norm_apply(cfg.norm, x, lp_["ln1"])
                    q, k, v = attn_mod.qkv_proj(h, lp_["attn"], cfg)
                    o = attn_mod.attend(q, k, v, mask=None)
                    x = x + attn_mod.out_proj(o, lp_["attn"])
                    x = x + mlp_apply(norm_apply(cfg.norm, x, lp_["ln2"]), lp_["mlp"], cfg)
                    return x, None
                if train and model.remat:
                    body = jax.checkpoint(body)
                x, _ = jax.lax.scan(body, x, lp)
                return x

            def dec_fwd(lp, xe_and_x):
                xe, x = xe_and_x
                positions = jnp.arange(x.shape[1], dtype=jnp.int32)

                def body(x, lp_):
                    h = norm_apply(cfg.norm, x, lp_["ln1"])
                    a, _, _ = attn_mod.attention_prefill(h, lp_["attn"], cfg,
                                                         positions, rope=False)
                    x = x + a
                    h = norm_apply(cfg.norm, x, lp_["lnx"])
                    ck_, cv_ = attn_mod.cross_kv(xe, lp_["cross"], cfg)
                    x = x + attn_mod.cross_attention(h, lp_["cross"], cfg, ck_, cv_)
                    x = x + mlp_apply(norm_apply(cfg.norm, x, lp_["ln2"]), lp_["mlp"], cfg)
                    return x, None
                if train and model.remat:
                    body = jax.checkpoint(body)
                x, _ = jax.lax.scan(body, x, lp)
                return x

            efn = (lambda lp, x: jax.grad(lambda l_, x_: jnp.sum(
                enc_fwd(l_, x_).astype(jnp.float32)))(lp, x)) if train else enc_fwd
            dfn = (lambda lp, xx: jax.grad(lambda l_, x_: jnp.sum(
                dec_fwd(l_, x_).astype(jnp.float32)))(lp, xx)) if train else dec_fwd
            out.append(Lowerable("enc_layer", efn, (enc1, xe_spec), (enc_sh, xe_sh),
                                 None, multiplier=cfg.num_encoder_layers - 1))
            out.append(Lowerable("dec_layer", dfn, (dec1, (xe_spec, x_spec)),
                                 (dec_sh, (xe_sh, x_sh)), None,
                                 multiplier=cfg.num_layers - 1))
    return out
