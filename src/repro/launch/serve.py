"""Serving driver: the full DéjàVu system on an in-process cluster.

``python -m repro.launch.serve --arch gpt2-1.5b --reduced --workers 4 \
      --mode disaggregated --swapping --replication --fail-at 12:1``

Runs synthetic requests through the pipeline-parallel cluster with the
selected DéjàVu features and prints the report (tokens, transfers, recovery
events).  The planner picks the prompt/token split unless --dp-split is
given.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import costmodel as cm
from repro.core.planner import plan
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--mode", choices=["colocated", "disaggregated"],
                    default="colocated")
    ap.add_argument("--dp-split", default=None, help="e.g. 2:2")
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--swapping", action="store_true")
    ap.add_argument("--replication", action="store_true")
    ap.add_argument("--compress-replicas", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--fail-at", default=None, help="step:worker, e.g. 12:1")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        import dataclasses
        cfg = dataclasses.replace(cfg.reduced(), num_layers=max(8, args.workers))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    dp_split = None
    if args.mode == "disaggregated":
        if args.dp_split:
            a, b = args.dp_split.split(":")
            dp_split = (int(a), int(b))
        else:
            wl = cm.WorkloadSpec(args.prompt_len, args.max_new, args.microbatch)
            p = plan(cfg, wl, args.workers)
            dp_split = ((p.d_prompt, p.d_token) if p.feasible
                        else (max(1, args.workers // 4),
                              args.workers - max(1, args.workers // 4)))
            print(f"planner split: Dp={dp_split[0]} Dt={dp_split[1]}")

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]

    eng = ServingEngine(cfg, model, params, args.workers, mode=args.mode,
                        dp_split=dp_split, microbatch=args.microbatch,
                        swapping=args.swapping, replication=args.replication,
                        compress_replicas=args.compress_replicas)
    fail_at = None
    if args.fail_at:
        s, w = args.fail_at.split(":")
        fail_at = {int(s): int(w)}
    report = eng.run(reqs, fail_at=fail_at)
    print(f"steps={report.steps_executed} redone={report.steps_redone} "
          f"failures={report.failures} recoveries={report.recoveries}")
    print("transfers:", eng.transfer_summary())
    for rid in sorted(report.tokens)[:4]:
        print(f"req {rid}: {report.tokens[rid]}")
    for ev in eng.cluster.controller.events:
        print("event:", {k: v for k, v in ev.items() if k != 't'})


if __name__ == "__main__":
    main()
