import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  Everything below may import jax.

import argparse
import json
import re
import time
import traceback
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES, get_arch, supports_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, make_cell  # noqa: F401 (public API)

# ---------------------------------------------------------------------------
# v5e hardware constants (per chip)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_LINK_BW = 50e9           # bytes/s per link

_DTYPES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f16": 2, "bf16": 2,
           "s16": 2, "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8,
           "s64": 8, "u64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPES) + r")\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _result_bytes(line: str) -> int:
    """Sum result-buffer bytes on an HLO instruction line (lhs of '=')."""
    lhs = line.split(" = ", 1)
    text = lhs[1] if len(lhs) == 2 else line
    # result types appear before the op name; operands are %refs (no types)
    head = text.split("(", 1)[0]
    total = 0
    for dt, dims in _SHAPE_RE.findall(head):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device operand bytes of every collective op in the HLO.

    all-gather: operand = result / group_size; reduce-scatter: operand =
    result * group_size; others: operand = result.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("%") or stripped.startswith("ROOT"):
            op = next((c for c in _COLLECTIVES if f" {c}(" in stripped
                       or f" {c}-start(" in stripped), None)
            if op is None:
                continue
            rb = _result_bytes(stripped)
            m = _GROUP_RE.search(stripped)
            gsz = int(m.group(2)) if m else 1
            if op == "all-gather":
                rb = rb / max(gsz, 1)
            elif op == "reduce-scatter":
                rb = rb * gsz
            out[op] += rb
    return out


def analyse_lowerable(low, mesh) -> Dict:
    with jax.set_mesh(mesh):
        jitted = jax.jit(low.fn, in_shardings=low.in_shardings,
                         out_shardings=low.out_shardings,
                         donate_argnums=low.donate or ())
        t0 = time.time()
        lowered = jitted.lower(*low.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "name": low.name,
        "multiplier": low.multiplier,
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "collective_total": float(sum(coll.values())),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "lower_s": t_lower,
        "compile_s": t_compile,
    }


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             variant: str = "baseline") -> Dict:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = make_cell(cfg, shape, mesh, variant=variant)
    parts = []
    for low in cell:
        parts.append(analyse_lowerable(low, mesh))

    step = parts[0]
    flops = step["flops"] + sum(p["flops"] * p["multiplier"] for p in parts[1:])
    mem_bytes = step["bytes_accessed"] + sum(
        p["bytes_accessed"] * p["multiplier"] for p in parts[1:])
    coll = step["collective_total"] + sum(
        p["collective_total"] * p["multiplier"] for p in parts[1:])

    n_chips = int(np.prod(mesh.devices.shape))
    # model FLOPs (per device): 6·N·D train / 2·N·D forward, MoE uses active N
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * shape.global_batch
    model_flops_per_dev = model_flops / n_chips

    compute_s = flops / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    collective_s = coll / ICI_LINK_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    hbm_per_dev = (step["memory"]["argument_bytes"] + step["memory"]["temp_bytes"]
                   + step["memory"]["output_bytes"])
    return {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "step": step["name"],
        "chips": n_chips,
        "per_device": {
            "flops": flops, "bytes_accessed": mem_bytes,
            "collective_bytes": coll,
            "argument_bytes": step["memory"]["argument_bytes"],
            "temp_bytes": step["memory"]["temp_bytes"],
            "output_bytes": step["memory"]["output_bytes"],
            "hbm_total_bytes": hbm_per_dev,
        },
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant,
            "model_flops_per_dev": model_flops_per_dev,
            "useful_flops_ratio": (model_flops_per_dev / flops) if flops else 0.0,
        },
        "fits_hbm": bool(hbm_per_dev <= 16e9),
        "collective_breakdown": {
            k: step["collective_bytes"][k] + sum(
                p["collective_bytes"][k] * p["multiplier"] for p in parts[1:])
            for k in step["collective_bytes"]},
        "parts": parts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod compile-only dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--variant", choices=["baseline", "opt", "opt-zmlp"], default="baseline")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results: List[Dict] = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                meshname = "2x16x16" if mp else "16x16"
                if (arch, shape, meshname) in done:
                    continue
                t0 = time.time()
                try:
                    res = run_cell(arch, shape, mp, variant=args.variant)
                except Exception as e:  # a failure here is a bug in the system
                    res = {"arch": arch, "shape": shape, "mesh": meshname,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                res["wall_s"] = time.time() - t0
                res["variant"] = args.variant
                results.append(res)
                _summ(res)
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"\nwrote {args.out} ({len(results)} cells)")


def _summ(res: Dict) -> None:
    tag = f"{res['arch']:24s} {res['shape']:12s} {res['mesh']:8s}"
    if res["status"] == "skipped":
        print(f"{tag} SKIP ({res['reason'][:60]}...)")
        return
    if res["status"] == "error":
        print(f"{tag} ERROR {res['error'][:100]}")
        return
    r = res["roofline"]
    pd = res["per_device"]
    print(f"{tag} ok  hbm/dev={pd['hbm_total_bytes']/1e9:6.2f}GB "
          f"compute={r['compute_s']*1e3:8.3f}ms memory={r['memory_s']*1e3:8.3f}ms "
          f"coll={r['collective_s']*1e3:8.3f}ms dom={r['dominant']:10s} "
          f"useful={r['useful_flops_ratio']*100:5.1f}% [{res['wall_s']:.0f}s]")


if __name__ == "__main__":
    main()
