"""Training driver: ``python -m repro.launch.train --arch smollm-360m --reduced``

Fault-tolerant by construction: checkpoints every --ckpt-every steps with
atomic manifests and auto-resumes from the latest valid step on restart
(kill it mid-run and re-launch to see).  On this CPU container use --reduced;
on a real pod the same driver shards params/optimizer per
distributed/sharding.py over the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.training import (SyntheticDataPipeline, adamw_init, latest_step,
                            make_train_step, restore_checkpoint, save_checkpoint)
from repro.training.train import TrainConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=not args.reduced)
    data = SyntheticDataPipeline(cfg.vocab_size, args.seq, args.batch,
                                 seed=args.seed, family=cfg.family,
                                 d_model=cfg.d_model,
                                 num_patches=cfg.num_patches,
                                 src_len=min(cfg.max_source_len, args.seq))
    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    start = 0
    resumed = latest_step(args.ckpt_dir)
    if resumed is not None:
        (state, start) = restore_checkpoint(args.ckpt_dir,
                                            {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(model, TrainConfig(lr=args.lr,
                                                         grad_accum=args.grad_accum)))
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if (step + 1) % args.log_every == 0:
            print(f"step {step+1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/args.log_every:.2f}s/step)")
            t0 = time.time()
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            path = save_checkpoint(args.ckpt_dir, step + 1,
                                   {"params": params, "opt": opt})
            print(f"checkpointed -> {path}")


if __name__ == "__main__":
    main()
