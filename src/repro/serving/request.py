"""Request + microbatch lifecycle."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int
    eos_id: Optional[int] = None
    arrival: float = 0.0
    tokens: List[int] = field(default_factory=list)
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclass
class Microbatch:
    mb: int
    requests: List[Request]
    next_step: int = 0            # 0 = needs prefill; i>=1 = next decode step
    n_new: int = 0                # synchronous token budget (max over requests)
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return self.requests[0].prompt_len

    def batch_prompts(self) -> np.ndarray:
        return np.stack([r.prompt for r in self.requests]).astype(np.int32)


def form_microbatches(requests: List[Request], size: int) -> List[Microbatch]:
    """Group fixed-size microbatches; prompts inside one microbatch must share
    a length (the paper's setting — fixed prompt size per experiment)."""
    mbs = []
    for i in range(0, len(requests), size):
        group = requests[i: i + size]
        lens = {r.prompt_len for r in group}
        assert len(lens) == 1, "prompts within a microbatch must share length"
        mbs.append(Microbatch(mb=len(mbs), requests=group,
                              n_new=max(r.max_new for r in group)))
    return mbs
