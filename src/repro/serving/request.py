"""Request + microbatch lifecycle."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int
    eos_id: Optional[int] = None
    arrival: float = 0.0
    tokens: List[int] = field(default_factory=list)
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclass
class Microbatch:
    mb: int
    requests: List[Request]
    next_step: int = 0            # 0 = needs prefill; i>=1 = next decode step
    n_new: int = 0                # synchronous token budget (max over requests)
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return self.requests[0].prompt_len

    def batch_prompts(self) -> np.ndarray:
        return np.stack([r.prompt for r in self.requests]).astype(np.int32)


def form_microbatches(requests: List[Request], size: int) -> List[Microbatch]:
    """Group fixed-size, length-homogeneous microbatches.

    Prompts inside one microbatch must share a length (the paper's setting —
    fixed prompt size per experiment), so a mixed-length trace is bucketed by
    prompt length first (arrival order preserved within a bucket; each
    bucket's tail microbatch may be smaller than `size`)."""
    order: List[int] = []
    buckets = {}
    for r in requests:
        if r.prompt_len not in buckets:
            order.append(r.prompt_len)
        buckets.setdefault(r.prompt_len, []).append(r)
    mbs = []
    for plen in order:
        bucket = buckets[plen]
        for i in range(0, len(bucket), size):
            group = bucket[i: i + size]
            mbs.append(Microbatch(mb=len(mbs), requests=group,
                                  n_new=max(r.max_new for r in group)))
    return mbs
