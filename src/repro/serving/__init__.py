from repro.serving.request import Request
from repro.serving.engine import ServingEngine, EngineReport

__all__ = ["Request", "ServingEngine", "EngineReport"]
