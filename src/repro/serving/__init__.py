from repro.serving.engine import EngineReport, ServingEngine
from repro.serving.request import Request
from repro.serving.scheduler import RoundScheduler, StepPlan

__all__ = ["EngineReport", "Request", "RoundScheduler", "ServingEngine",
           "StepPlan"]
