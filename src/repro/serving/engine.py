"""ServingEngine: serving loops over a DejaVuCluster.

Two schedulers share the cluster, the sampler, and the failure machinery:

`run` — microbatch round-robin (FasterTransformer semantics, the paper's
setting): in-flight microbatch slots advance one step per round; a slot only
frees when its WHOLE microbatch drains, and each microbatch holds a padded
prompt+max_new cache for its entire lifetime.

`run_continuous` — continuous batching over the paged KV pool
(`paged=True`): requests are admitted into the running batch the moment
blocks free up, finished sequences retire (and release their blocks)
immediately, and a full pool preempts the youngest sequence (block-granular
swap-out) instead of stalling.  With greedy sampling its outputs are
bit-identical to `run`'s, which tests assert.

`tiered=True` additionally backs every stage's pool with the HBM→host→SSD
hierarchy of `repro.kvcache.tiers`: preemption swaps through the tiers
(write-behind, spilling to SSD under host pressure), retired prompt blocks
enter a persistent prefix cache, and a new request whose prompt prefix
matches streams those blocks back in instead of re-prefilling them
(`EngineReport.prefill_tokens_saved` / `tier_stats`).

Failure injection / detection / 4-step recovery run between steps in both
loops; recovered work rolls back to its last replicated step and regenerates
bit-identically.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import telemetry
from repro.core import tracing
from repro.core.cluster import DejaVuCluster
from repro.core.dejavulib import faults
from repro.core.dejavulib.transport import DEFAULT_HW, HardwareModel
from repro.kvcache.paged import PoolExhausted
from repro.serving.request import Microbatch, Request, form_microbatches
from repro.serving.sampling import greedy
from repro.serving.scheduler import RoundScheduler, StepPlan


class _SingleSeq:
    """Adapter: one request viewed as a 1-element microbatch for `_emit`."""

    def __init__(self, r: Request):
        self.requests = [r]


@dataclass
class EngineReport:
    tokens: Dict[int, List[int]]            # rid -> generated tokens
    steps_executed: int = 0
    steps_redone: int = 0
    failures: int = 0
    recoveries: int = 0
    preemptions: int = 0
    peak_kv_bytes: int = 0
    # one entry per continuous-batching round: live batch size that round
    batch_trace: List[int] = field(default_factory=list)
    transfer_bytes: Dict[str, int] = field(default_factory=dict)
    events: List[dict] = field(default_factory=list)
    # cross-request prefix reuse through the tier hierarchy (tiered=True)
    prefill_tokens_total: int = 0
    prefill_tokens_saved: int = 0           # prompt tokens served from cache
    tier_stats: Dict[str, float] = field(default_factory=dict)
    # one entry per continuous-batching round that executed >=1 decode step:
    # modeled prefill seconds co-scheduled in that round (the decode stall a
    # long prompt inflicts; chunk-interleaving bounds it to one chunk pass)
    prefill_stall_trace: List[float] = field(default_factory=list)
    # one entry per continuous-batching round: pipeline passes executed that
    # round.  Fused rounds run ONE batched decode pass (plus one chunk-set
    # pass while prefills are in flight, plus admission first-passes); the
    # per-sequence oracle path runs one pass per live sequence per round.
    pass_trace: List[int] = field(default_factory=list)
    # one dict per fault the run's FaultInjector realized (point, n, kind,
    # tag, wid) — lets tests assert WHERE a fault landed, not just that
    # failures/recoveries were counted (see repro.core.dejavulib.faults)
    fault_trace: List[dict] = field(default_factory=list)
    # telemetry snapshot (schema `repro.telemetry/v1`): counters, gauges,
    # SLO histograms (TTFT / inter-token / queue wait / recovery time) and
    # span aggregates on the modeled clock — see repro.core.telemetry and
    # docs/observability.md.  Cumulative across runs when an ambient
    # registry is installed (benchmarks do this to aggregate a module).
    telemetry: Dict[str, object] = field(default_factory=dict)


class ServingEngine:
    def __init__(self, cfg: ArchConfig, model, params, n_workers: int, *,
                 mode: str = "colocated",
                 dp_split: Optional[tuple] = None,
                 microbatch: int = 2,
                 swapping: bool = False, replication: bool = False,
                 compress_replicas: bool = False,
                 paged: bool = False, kv_block_size: Optional[int] = None,
                 kv_pool_blocks: Optional[int] = None,
                 tiered: bool = False,
                 host_cache_blocks: Optional[int] = None,
                 ssd_cache_blocks: Optional[int] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 fused_rounds: Optional[bool] = None,
                 hw: HardwareModel = DEFAULT_HW,
                 sampler: Callable = greedy):
        self.cfg = cfg
        self.microbatch = microbatch
        self.sampler = sampler
        self.cluster = DejaVuCluster(cfg, model, params, n_workers, mode=mode,
                                     dp_split=dp_split, swapping=swapping,
                                     replication=replication,
                                     compress_replicas=compress_replicas, hw=hw,
                                     paged=paged, kv_block_size=kv_block_size,
                                     kv_pool_blocks=kv_pool_blocks,
                                     tiered=tiered,
                                     host_cache_blocks=host_cache_blocks,
                                     ssd_cache_blocks=ssd_cache_blocks,
                                     prefill_chunk_tokens=prefill_chunk_tokens,
                                     fused_rounds=fused_rounds)
        # rid -> modeled clock of its last emitted token (inter-token SLO)
        self._emit_clock: Dict[int, float] = {}

    # ------------------------------------------------------------------
    # telemetry plumbing (shared by both serving loops)
    # ------------------------------------------------------------------
    def _install_telemetry(self) -> Tuple[telemetry.Telemetry, bool]:
        """Reuse the ambient registry when one is installed (benchmarks
        install one per module to aggregate across runs); otherwise create
        a fresh per-run registry.  Returns (registry, created)."""
        t = telemetry.current()
        if t is not None:
            return t, False
        t = telemetry.Telemetry()
        telemetry.install(t)
        return t, True

    @staticmethod
    def _teardown_telemetry(t: telemetry.Telemetry, created: bool,
                            report: EngineReport) -> None:
        report.telemetry = t.snapshot()
        if created:
            telemetry.uninstall()

    def _tele_emit(self, requests: List[Request], i: int) -> None:
        """Per-token SLO observations at emit time, on the modeled clock:
        TTFT (arrival -> first token), inter-token gap, and — at the first
        token emitted after a failure's restore — the recovery-time span."""
        t = telemetry.current()
        if t is None:
            return
        now = t.clock_s
        trc = tracing.active()
        for r in requests:
            if i == 0:
                ttft = max(now - r.arrival, 0.0)
                t.observe("engine.ttft_s", ttft)
                if trc:
                    tracing.event("emit.first_token", rid=r.rid,
                                  ttft_ns=int(round(ttft * 1e9)))
            else:
                prev = self._emit_clock.get(r.rid)
                if prev is not None:
                    t.observe("engine.inter_token_s", max(now - prev, 0.0))
            self._emit_clock[r.rid] = now
        for mark in self.cluster.take_recovery_marks():
            rec = max(now - mark, 0.0)
            t.observe("cluster.recovery_s", rec)
            if trc:
                # failure -> first post-restore token, on the modeled clock
                tracing.event("recovery.first_token",
                              recovery_ns=int(round(rec * 1e9)))

    # ------------------------------------------------------------------
    # fault-injection plumbing (shared by both serving loops)
    # ------------------------------------------------------------------
    def _install_faults(self, fail_at, fault_plan, fault_injector,
                        report: EngineReport
                        ) -> Tuple[Optional[faults.FaultInjector], object]:
        """Bind this run's FaultInjector and install it as the process-wide
        active injector.  The legacy ``fail_at={gstep: wid}`` kwarg becomes
        ``engine.step`` worker_death specs (that point fires exactly once
        per scheduled step, so occurrence == gstep).  Returns (injector,
        previously-active injector) for `_teardown_faults`."""
        if fault_injector is None and not fail_at and fault_plan is None:
            return None, None
        inj = fault_injector if fault_injector is not None \
            else faults.FaultInjector(fault_plan)
        for g, w in sorted((fail_at or {}).items()):
            inj.plan.add(faults.FaultSpec("engine.step", nth=g,
                                          kind="worker_death", wid=w))

        def _kill(wid):
            self.cluster.inject_failure(wid)
            report.failures += 1

        inj.worker_killer = _kill
        prev = faults.current()
        faults.install(inj)
        return inj, prev

    @staticmethod
    def _teardown_faults(inj, prev, report: EngineReport) -> None:
        if inj is None:
            return
        if prev is None:
            faults.uninstall()
        else:
            faults.install(prev)
        report.fault_trace = [asdict(f) for f in inj.fired]

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *,
            fail_at: Optional[Dict[int, int]] = None,
            migrate_at: Optional[Dict[int, int]] = None,
            repartition_at: Optional[Dict[int, int]] = None,
            fault_plan: Optional[faults.FaultPlan] = None,
            fault_injector: Optional[faults.FaultInjector] = None
            ) -> EngineReport:
        """fail_at / migrate_at: {global_step: worker_id}; repartition_at:
        {global_step: new_depth}.  `fault_plan` / `fault_injector` drive the
        general injection layer (`repro.core.dejavulib.faults`); `fail_at`
        is the legacy shim for worker death at a step boundary."""
        migrate_at = dict(migrate_at or {})
        repartition_at = dict(repartition_at or {})
        mbs = form_microbatches(requests, self.microbatch)
        queue = list(mbs)
        depth = len(self.cluster.token_group)
        slots: List[Optional[Microbatch]] = [None] * depth
        report = EngineReport(tokens={r.rid: r.tokens for r in requests})
        inj, prev = self._install_faults(fail_at, fault_plan, fault_injector,
                                         report)
        tele, tele_created = self._install_telemetry()
        self._emit_clock = {}
        gstep = 0
        slot_rounds = slot_busy = 0   # microbatch-slot occupancy -> bubbles

        def active_ids() -> List[int]:
            return [s.mb for s in slots if s is not None]

        try:
            while any(s is not None for s in slots) or queue:
                for q in range(depth):
                    if slots[q] is None and queue:
                        slots[q] = queue.pop(0)
                slot_rounds += depth
                slot_busy += sum(s is not None for s in slots)
                with telemetry.span("round"), tracing.span("round"):
                    for q in range(depth):
                        mb = slots[q]
                        if mb is None:
                            continue
                        gstep += 1
                        # --- scheduled control events -----------------------
                        faults.fire("engine.step", tag=f"mb{mb.mb}")
                        if gstep in migrate_at:
                            res = self.cluster.migrate_worker(
                                migrate_at.pop(gstep), active_ids())
                            report.recoveries += 1
                            self._apply_resume(res, slots, report)
                        if gstep in repartition_at:
                            self.cluster.repartition(
                                repartition_at.pop(gstep), active_ids())

                        # --- advance this slot one step ---------------------
                        try:
                            self._advance(mb, report)
                        except RuntimeError:
                            # dead worker hit mid-pipeline: detect + recover
                            resume = self.cluster.detect_and_recover(
                                active_ids())
                            report.recoveries += 1
                            self._apply_resume(resume, slots, report)
                            self._advance(mb, report)  # re-execute the step
                        if mb.done:
                            slots[q] = None
        finally:
            # empty microbatch slots ARE the pipeline bubbles of the paper's
            # joint/FasterTransformer setting (slots drain at the speed of
            # their slowest member)
            tele.gauge("engine.bubble_frac",
                       1.0 - slot_busy / slot_rounds if slot_rounds else 0.0)
            self._teardown_faults(inj, prev, report)
            self._teardown_telemetry(tele, tele_created, report)
        report.peak_kv_bytes = self.cluster.kv_bytes_peak
        return report

    # ------------------------------------------------------------------
    # continuous batching over the paged KV pool
    # ------------------------------------------------------------------
    def run_continuous(self, requests: List[Request], *,
                       max_active: int = 4,
                       fail_at: Optional[Dict[int, int]] = None,
                       fault_plan: Optional[faults.FaultPlan] = None,
                       fault_injector: Optional[faults.FaultInjector] = None
                       ) -> EngineReport:
        """Continuous-batching loop (requires ``paged=True``).

        The policy (admission, resume, preemption victims, retirement) lives
        in `RoundScheduler`; this method is the thin driver that executes one
        `StepPlan` per round: (1) the scheduler resumes preempted / admits
        queued requests into freed pool space, (2) every live request
        advances one step, (3) finished requests retire, returning their
        blocks.  `fail_at` counts per-request steps exactly like `run`'s
        global steps.  Each request generates exactly `max_new` tokens (or
        stops at eos) — unlike `run`, no request is held hostage by the
        longest peer in its microbatch.

        Prompts longer than `prefill_chunk_tokens` prefill CHUNK-INTERLEAVED:
        each round runs one chunk pass per in-flight prefill alongside one
        decode step per running sequence, so a long prompt stalls co-resident
        decodes by at most one chunk instead of its whole length
        (`EngineReport.prefill_stall_trace` records the per-round stall).

        Fused rounds are the DEFAULT (`ArchConfig.fused_rounds=True`): for
        every config the cluster's `fused_ok` gate accepts — all dense/moe
        attention variants, ALiBi (bloom) and sliding-window+meta included —
        the round's decodes run as ONE batched pipeline pass over ragged
        per-sequence lengths and all in-flight chunk prefills pack into one
        chunk-set pass — `EngineReport.pass_trace` records the per-round
        pass count — with outputs token-identical to the per-sequence
        oracle path.  Pass ``fused_rounds=False`` to the engine to force
        the oracle path; unsupported families (ssm/hybrid/encdec/vlm) fall
        back to it automatically.
        """
        cl = self.cluster
        assert cl.paged, "run_continuous requires ServingEngine(..., paged=True)"
        sched = RoundScheduler(cl, requests, max_active=max_active)
        report = EngineReport(tokens={r.rid: r.tokens for r in requests})
        inj, prev = self._install_faults(fail_at, fault_plan, fault_injector,
                                         report)
        tele, tele_created = self._install_telemetry()
        self._emit_clock = {}
        clock0 = tele.clock_s
        fused = cl.fused_ok
        try:
            while sched.pending():
                cl.round_prefill_model_s = 0.0
                self._round_decodes = 0
                self._round_passes = 0
                with telemetry.span("round"), tracing.span("round"):
                    plan = sched.plan_round(
                        lambda r: self._advance_seq(r, sched, report))
                    report.batch_trace.append(plan.n_active)
                    if tracing.active():
                        tracing.event("sched.plan", round=plan.round_idx,
                                      n_active=plan.n_active,
                                      rids=[r.rid for r in plan.work])
                    if fused:
                        self._execute_round_fused(plan, sched, report)
                    else:
                        self._execute_round(plan, sched, report)
                    # --- retire finished sequences (blocks free at once) ----
                    sched.retire()
                if self._round_decodes:
                    report.prefill_stall_trace.append(cl.round_prefill_model_s)
                report.pass_trace.append(self._round_passes)
        finally:
            # bubble fraction: share of the run's modeled time that decodes
            # spent stalled behind co-scheduled prefill passes (chunked
            # prefill exists to bound exactly this)
            busy = tele.clock_s - clock0
            stall = sum(report.prefill_stall_trace)
            tele.gauge("engine.bubble_frac",
                       stall / busy if busy > 0.0 else 0.0)
            self._teardown_faults(inj, prev, report)
            self._teardown_telemetry(tele, tele_created, report)
        report.peak_kv_bytes = cl.kv_bytes_peak
        report.prefill_tokens_total = cl.prefill_tokens_total
        report.prefill_tokens_saved = cl.prefill_tokens_saved
        if cl.tiered:
            report.tier_stats = cl.tier_stats()
        return report

    # ------------------------------------------------------------------
    # per-sequence oracle path: one pipeline pass per request per round
    # ------------------------------------------------------------------
    def _execute_round(self, plan: StepPlan, sched: RoundScheduler,
                       report: EngineReport) -> None:
        for r in plan.work:
            if not sched.is_active(r.rid):
                continue        # dropped by a mid-round preemption
            if sched.next_step[r.rid] >= r.max_new or r.done:
                continue        # budget spent at admission (or eos'd)
            while True:
                try:
                    self._advance_seq(r, sched, report)
                    break
                except PoolExhausted:
                    self._preempt_victim_or_raise(sched, report,
                                                  exclude=(r.rid,))

    # ------------------------------------------------------------------
    # fused rounds: ONE batched pass per round (+ one chunk-set pass while
    # prefills are in flight)
    # ------------------------------------------------------------------
    def _execute_round_fused(self, plan: StepPlan, sched: RoundScheduler,
                             report: EngineReport) -> None:
        # snapshot the round's split BEFORE running anything: like the oracle
        # path, every request advances ONE step per round — a prompt whose
        # prefill completes this round decodes only from the NEXT round on
        pf = [r for r in plan.work if sched.is_active(r.rid)
              and sched.next_step[r.rid] == 0 and not r.done]
        dec0 = [r for r in plan.work if sched.next_step[r.rid] >= 1]
        if pf and not self._fused_prefill_pass(pf, sched, report):
            return              # a worker died: recovered state runs next round
        while True:
            dec = [r for r in dec0 if sched.is_active(r.rid) and not r.done
                   and 1 <= sched.next_step[r.rid] < r.max_new]
            if not dec:
                return
            try:
                self._fused_decode_pass(dec, sched, report)
                return
            except PoolExhausted:
                # same victim policy as the oracle path, except the whole
                # batch is "the current request": shrink the round instead —
                # preempt the youngest resident sequence (possibly a batch
                # member) and retry the pass without it
                if len(dec) == 1:
                    self._preempt_victim_or_raise(sched, report,
                                                  exclude=(dec[0].rid,))
                else:
                    self._preempt_victim_or_raise(sched, report)

    def _preempt_victim_or_raise(self, sched: RoundScheduler,
                                 report: EngineReport,
                                 exclude=()) -> None:
        """Handle a full pool mid-round: swap out the scheduler's chosen
        victim and let the caller retry, or re-raise the active
        PoolExhausted when nothing preemptible remains."""
        victim = sched.pick_victim(exclude=exclude)
        if victim is None:
            raise
        self.cluster.preempt_seq(victim.rid)
        sched.preempt(victim)
        report.preemptions += 1

    def _fused_prefill_pass(self, pf: List[Request], sched: RoundScheduler,
                            report: EngineReport) -> bool:
        """Advance every in-flight prefill one chunk: chunk-mode prefills
        pack into ONE pipeline pass; oracle-mode ones (chunking disabled)
        fall back to one pass each.  Returns False if a worker death was
        recovered (the round ends; rolled-back work reruns next round)."""
        cl = self.cluster
        for r in pf:            # one logical step per packed prefill, so
            # engine.step occurrences land like the oracle path's
            faults.fire("engine.step", tag=f"prefill-r{r.rid}")
        try:
            for r in pf:
                # staging allocates (adopt_prefix / whole-prompt tables), and
                # the oracle-mode passes below append — both can hit a full
                # pool, which preempts a victim and retries like the oracle
                # path (a mid-prefill sequence is never a victim, so retrying
                # cannot disturb the prefills already staged)
                while not cl.prefill_pending(r.rid):
                    try:
                        cl.prefill_seq_begin(r.rid, r.prompt, r.max_new)
                    except PoolExhausted:
                        self._preempt_victim_or_raise(sched, report)
            chunk = [r for r in pf if cl.prefill_mode(r.rid) == "chunk"]
            rest = [r for r in pf if cl.prefill_mode(r.rid) != "chunk"]
            if chunk:
                out = cl.prefill_chunkset_pass([r.rid for r in chunk])
                self._round_passes += 1
                report.steps_executed += len(chunk)
                for r in chunk:
                    self._finish_prefill_step(r, out[r.rid], sched)
            for r in rest:
                while True:
                    try:
                        logits = cl.prefill_seq_step(r.rid)
                        break
                    except PoolExhausted:
                        self._preempt_victim_or_raise(sched, report)
                self._round_passes += 1
                report.steps_executed += 1
                self._finish_prefill_step(r, logits, sched)
        except RuntimeError:
            self._recover_fused(sched, report)
            return False
        return True

    def _finish_prefill_step(self, r: Request, logits, sched) -> None:
        if logits is None:
            return              # prefill still in flight
        tok = self.sampler(logits, 0)
        self._emit(_SingleSeq(r), tok, 0)
        sched.next_step[r.rid] = 1

    def _fused_decode_pass(self, dec: List[Request], sched: RoundScheduler,
                           report: EngineReport) -> None:
        cl = self.cluster
        for r in dec:
            faults.fire("engine.step", tag=f"decode-r{r.rid}")
        rids = [r.rid for r in dec]
        steps = [sched.next_step[r.rid] for r in dec]
        last = np.asarray([r.tokens[s - 1] for r, s in zip(dec, steps)],
                          np.int32)
        try:
            logits = cl.decode_batch(rids, last, steps)
        except RuntimeError:
            self._recover_fused(sched, report)
            return              # rolled-back steps rerun next round
        self._round_passes += 1
        for i, (r, s) in enumerate(zip(dec, steps)):
            tok = self.sampler(logits[i:i + 1], s)
            self._emit(_SingleSeq(r), tok, s)
            sched.next_step[r.rid] = s + 1
            self._round_decodes += 1
            report.steps_executed += 1

    def _recover_fused(self, sched: RoundScheduler,
                       report: EngineReport) -> None:
        """Detect-and-recover after a worker died inside a fused pass: every
        covered sequence rolls back to its last replicated step (mid-prefill
        ones restart from scratch), exactly like the per-sequence path —
        the next rounds regenerate the rolled-back tokens bit-identically."""
        cl = self.cluster
        covered = sched.covered()
        live = [a.rid for a in covered if not a.done]
        resume = cl.detect_and_recover(live)
        report.recoveries += 1
        self._apply_resume_seqs(resume, covered, sched.next_step, report)
        for rr in covered:
            if sched.next_step.get(rr.rid, 1) == 0:
                cl.abort_prefill(rr.rid)

    def _advance_seq(self, r: Request, sched: RoundScheduler,
                     report: EngineReport) -> None:
        """One per-request step (prefill if next_step==0, else decode), with
        the same failure-injection / detect-recover contract as `_advance`.
        Preempted sequences join the recovery set: their swap copies on the
        failed worker die with it, so they too must rebuild from replicas
        and roll back."""
        cl = self.cluster
        next_step = sched.next_step
        faults.fire("engine.step", tag=f"r{r.rid}")
        covered = sched.covered()
        live = [a.rid for a in covered if not a.done]
        if r.rid not in live:
            live.append(r.rid)
        try:
            self._step_seq(r, next_step, report)
        except RuntimeError:
            resume = cl.detect_and_recover(live)
            report.recoveries += 1
            self._apply_resume_seqs(resume, covered + [r], next_step, report)
            # a worker death takes mid-prefill partial tables with it (their
            # sequences have no replicated steps to restore from): restart
            # those prefills from scratch on the recovered cluster
            for rr in covered + [r]:
                if next_step.get(rr.rid, 1) == 0:
                    cl.abort_prefill(rr.rid)
            self._step_seq(r, next_step, report)

    def _step_seq(self, r: Request, next_step: Dict[int, int],
                  report: EngineReport) -> None:
        """One pipeline pass for one request: a (chunk of) prefill while
        next_step is 0 — next_step stays 0 until the final chunk returns the
        prefill logits — else one decode step."""
        cl = self.cluster
        i = next_step[r.rid]
        self._round_passes += 1
        if i == 0:
            if not cl.prefill_pending(r.rid):
                cl.prefill_seq_begin(r.rid, r.prompt, r.max_new)
            logits = cl.prefill_seq_step(r.rid)
            report.steps_executed += 1
            if logits is None:
                return                   # prefill still in flight
            tok = self.sampler(logits, 0)
        else:
            last = np.asarray([r.tokens[i - 1]], np.int32)
            logits = cl.decode_seq(r.rid, jnp.asarray(last), i)
            tok = self.sampler(logits, i)
            self._round_decodes += 1
            report.steps_executed += 1
        self._emit(_SingleSeq(r), tok, i)
        next_step[r.rid] = i + 1

    def _apply_resume_seqs(self, resume: Dict[int, int],
                           requests: List[Request],
                           next_step: Dict[int, int],
                           report: EngineReport) -> None:
        seen = set()
        for r in requests:
            if r.rid in seen or r.rid not in resume:
                continue
            seen.add(r.rid)
            rr = max(resume[r.rid], 0)
            redone = max(0, next_step[r.rid] - rr)
            report.steps_redone += redone
            next_step[r.rid] = min(next_step[r.rid], rr)
            del r.tokens[next_step[r.rid]:]

    # ------------------------------------------------------------------
    def _advance(self, mb: Microbatch, report: EngineReport) -> None:
        cl = self.cluster
        if mb.next_step == 0:
            tokens = jnp.asarray(mb.batch_prompts())
            logits = cl.prefill_mb(mb.mb, tokens, mb.n_new)
            tok = self.sampler(logits, 0)
            self._emit(mb, tok, 0)
            mb.next_step = 1
        else:
            i = mb.next_step
            last = np.asarray([r.tokens[i - 1] if len(r.tokens) >= i else 0
                               for r in mb.requests], np.int32)
            logits = cl.decode_mb(mb.mb, jnp.asarray(last), i)
            tok = self.sampler(logits, i)
            self._emit(mb, tok, i)
            mb.next_step = i + 1
        report.steps_executed += 1
        # n_new tokens total: token_0 from prefill + decode steps 1..n_new-1
        if mb.next_step >= mb.n_new:
            mb.done = True

    def _emit(self, mb: Microbatch, tok: np.ndarray, i: int) -> None:
        for b, r in enumerate(mb.requests):
            if len(r.tokens) == i:
                r.tokens.append(int(tok[b]))
            else:                      # regeneration after rollback
                r.tokens[i] = int(tok[b])
            if r.eos_id is not None and int(tok[b]) == r.eos_id:
                r.done = True
        self._tele_emit(mb.requests, i)

    def _apply_resume(self, resume: Dict[int, int],
                      slots: List[Optional[Microbatch]],
                      report: EngineReport) -> None:
        for s in slots:
            if s is not None and s.mb in resume:
                r = resume[s.mb]
                redone = max(0, s.next_step - r)
                report.steps_redone += redone
                s.next_step = min(s.next_step, max(r, 0))
                for req in s.requests:   # truncate tokens beyond resume point
                    del req.tokens[s.next_step:]

    # ------------------------------------------------------------------
    def transfer_summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        groups = set(self.cluster.prompt_group + self.cluster.token_group)
        transports = [self.cluster.net]
        for w in groups:
            transports += [w.cache.net, w.cache.hostlink, w.cache.local]
            if getattr(w, "tier", None) is not None:
                transports += [w.tier.hostlink, w.tier.ssdlink]
        for t in transports:
            out[t.kind] = out.get(t.kind, 0) + t.bytes_total()
        return out
