"""ServingEngine: microbatch round-robin serving loop over a DejaVuCluster.

Mirrors the strict round-robin schedule of `core.schedule.rr_schedule`
(FasterTransformer semantics): in-flight microbatch slots advance one step per
round; early-stopped slots are backfilled from the queue.  Failure injection /
detection / 4-step recovery run between steps; recovered microbatches roll
back to their last replicated step and regenerate — with greedy sampling the
regenerated tokens are bit-identical (asserted in tests).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cluster import DejaVuCluster
from repro.core.dejavulib.transport import HardwareModel, DEFAULT_HW
from repro.serving.request import Microbatch, Request, form_microbatches
from repro.serving.sampling import greedy


@dataclass
class EngineReport:
    tokens: Dict[int, List[int]]            # rid -> generated tokens
    steps_executed: int = 0
    steps_redone: int = 0
    failures: int = 0
    recoveries: int = 0
    transfer_bytes: Dict[str, int] = field(default_factory=dict)
    events: List[dict] = field(default_factory=list)


class ServingEngine:
    def __init__(self, cfg: ArchConfig, model, params, n_workers: int, *,
                 mode: str = "colocated",
                 dp_split: Optional[tuple] = None,
                 microbatch: int = 2,
                 swapping: bool = False, replication: bool = False,
                 compress_replicas: bool = False,
                 hw: HardwareModel = DEFAULT_HW,
                 sampler: Callable = greedy):
        self.cfg = cfg
        self.microbatch = microbatch
        self.sampler = sampler
        self.cluster = DejaVuCluster(cfg, model, params, n_workers, mode=mode,
                                     dp_split=dp_split, swapping=swapping,
                                     replication=replication,
                                     compress_replicas=compress_replicas, hw=hw)

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *,
            fail_at: Optional[Dict[int, int]] = None,
            migrate_at: Optional[Dict[int, int]] = None,
            repartition_at: Optional[Dict[int, int]] = None) -> EngineReport:
        """fail_at / migrate_at: {global_step: worker_id}; repartition_at:
        {global_step: new_depth}."""
        fail_at = dict(fail_at or {})
        migrate_at = dict(migrate_at or {})
        repartition_at = dict(repartition_at or {})
        mbs = form_microbatches(requests, self.microbatch)
        queue = list(mbs)
        depth = len(self.cluster.token_group)
        slots: List[Optional[Microbatch]] = [None] * depth
        report = EngineReport(tokens={r.rid: r.tokens for r in requests})
        gstep = 0

        def active_ids() -> List[int]:
            return [s.mb for s in slots if s is not None]

        while any(s is not None for s in slots) or queue:
            for q in range(depth):
                if slots[q] is None and queue:
                    slots[q] = queue.pop(0)
            progressed = False
            for q in range(depth):
                mb = slots[q]
                if mb is None:
                    continue
                progressed = True
                gstep += 1
                # --- scheduled control events -------------------------------
                if gstep in fail_at:
                    self.cluster.inject_failure(fail_at.pop(gstep))
                    report.failures += 1
                if gstep in migrate_at:
                    res = self.cluster.migrate_worker(migrate_at.pop(gstep),
                                                      active_ids())
                    report.recoveries += 1
                    self._apply_resume(res, slots, report)
                if gstep in repartition_at:
                    self.cluster.repartition(repartition_at.pop(gstep), active_ids())

                # --- advance this slot one step ------------------------------
                try:
                    self._advance(mb, report)
                except RuntimeError:
                    # a dead worker was hit mid-pipeline: detect + recover
                    resume = self.cluster.detect_and_recover(active_ids())
                    report.recoveries += 1
                    self._apply_resume(resume, slots, report)
                    self._advance(mb, report)  # re-execute this slot's step
                if mb.done:
                    slots[q] = None
        return report

    # ------------------------------------------------------------------
    def _advance(self, mb: Microbatch, report: EngineReport) -> None:
        cl = self.cluster
        if mb.next_step == 0:
            tokens = jnp.asarray(mb.batch_prompts())
            logits = cl.prefill_mb(mb.mb, tokens, mb.n_new)
            tok = self.sampler(logits, 0)
            self._emit(mb, tok, 0)
            mb.next_step = 1
        else:
            i = mb.next_step
            last = np.asarray([r.tokens[i - 1] if len(r.tokens) >= i else 0
                               for r in mb.requests], np.int32)
            logits = cl.decode_mb(mb.mb, jnp.asarray(last), i)
            tok = self.sampler(logits, i)
            self._emit(mb, tok, i)
            mb.next_step = i + 1
        report.steps_executed += 1
        # n_new tokens total: token_0 from prefill + decode steps 1..n_new-1
        if mb.next_step >= mb.n_new:
            mb.done = True

    @staticmethod
    def _emit(mb: Microbatch, tok: np.ndarray, i: int) -> None:
        for b, r in enumerate(mb.requests):
            if len(r.tokens) == i:
                r.tokens.append(int(tok[b]))
            else:                      # regeneration after rollback
                r.tokens[i] = int(tok[b])
            if r.eos_id is not None and int(tok[b]) == r.eos_id:
                r.done = True

    def _apply_resume(self, resume: Dict[int, int],
                      slots: List[Optional[Microbatch]],
                      report: EngineReport) -> None:
        for s in slots:
            if s is not None and s.mb in resume:
                r = resume[s.mb]
                redone = max(0, s.next_step - r)
                report.steps_redone += redone
                s.next_step = min(s.next_step, max(r, 0))
                for req in s.requests:   # truncate tokens beyond resume point
                    del req.tokens[s.next_step:]

    # ------------------------------------------------------------------
    def transfer_summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        groups = set(self.cluster.prompt_group + self.cluster.token_group)
        transports = [self.cluster.net]
        for w in groups:
            transports += [w.cache.net, w.cache.hostlink, w.cache.local]
        for t in transports:
            out[t.kind] = out.get(t.kind, 0) + t.bytes_total()
        return out
