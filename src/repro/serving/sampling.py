"""Token sampling strategies (deterministic greedy is the default — required
for DéjàVu's recompute-after-recovery to regenerate identical tokens)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def greedy(logits, _step: int = 0) -> np.ndarray:
    return np.asarray(jnp.argmax(logits, axis=-1), np.int32)


class TopKSampler:
    """Seeded top-k/temperature sampling.  The per-(request, step) fold makes
    regeneration after failure recovery reproduce identical tokens."""

    def __init__(self, k: int = 40, temperature: float = 1.0, seed: int = 0):
        self.k = k
        self.temperature = temperature
        self.seed = seed

    def __call__(self, logits, step: int = 0) -> np.ndarray:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        vals, idx = jax.lax.top_k(logits / self.temperature, self.k)
        choice = jax.random.categorical(key, vals, axis=-1)
        return np.asarray(jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0],
                          np.int32)
