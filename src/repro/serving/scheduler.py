"""RoundScheduler / StepPlan: the continuous-batching policy layer.

`ServingEngine.run_continuous` used to be a monolith that mixed POLICY
(admission, resume, preemption-victim choice, retirement, prefill/decode
interleaving) with MECHANISM (pipeline passes, sampling, failure recovery).
The policy now lives here: the scheduler owns the request lifecycle state
(queue → active → preempted/retired) and emits one `StepPlan` per round;
the engine is a thin driver that executes each plan — as one fused batched
pipeline pass per round when `ArchConfig.fused_rounds` is on, or one pass
per sequence on the oracle path the fused path is property-tested against.

Bookkeeping is O(1) per event: the FIFO queues are `collections.deque`
(`popleft`, not ``list.pop(0)``) and active membership is an id-set (the
old loop rebuilt ``[a.rid for a in active]`` once per request per round —
quadratic in the active count exactly when the batch is large).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Iterable, List, Optional

from repro.core import telemetry
from repro.core import tracing
from repro.serving.request import Request


@dataclass
class StepPlan:
    """One continuous-batching round, as planned by `RoundScheduler`.

    `work` is the round's active set in admission order: every request in it
    gets one unit of progress this round — a prefill chunk pass while its
    `next_step` is 0, else one decode step.  The engine re-checks
    eligibility (membership, token budget, eos) at execution time, because
    mid-round preemption and failure rollback can change it after planning —
    exactly like the pre-refactor loop did.  Under fused rounds the engine
    executes all decodes in ONE batched pipeline pass and all chunk-mode
    prefills in one chunk-set pass; the oracle path runs one pass each.
    """
    round_idx: int
    n_active: int
    work: List[Request] = field(default_factory=list)


class RoundScheduler:
    """Admission / resume / preemption / retirement policy for
    `run_continuous` (engine-agnostic: it never runs a pipeline pass
    itself).

    Lifecycle per round: `plan_round` resumes preempted requests, admits
    queued ones while the pools fit them (a fresh admission runs its first
    step through the injected callback so the NEXT admission decision sees
    the pool state that step leaves behind), and snapshots the active set
    into a `StepPlan`; the engine executes it, calling `preempt` when a pool
    fills mid-round; `retire` then returns finished requests' blocks.
    """

    def __init__(self, cluster, requests: List[Request], *, max_active: int):
        self.cl = cluster
        self.max_active = max_active
        self.queue: Deque[Request] = deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        self.active: List[Request] = []
        self._active_ids: set = set()
        self.preempted: Deque[Request] = deque()
        self.next_step: Dict[int, int] = {r.rid: 0 for r in requests}
        self.rounds = 0

    # ------------------------------------------------------------------
    # state queries
    # ------------------------------------------------------------------
    def pending(self) -> bool:
        return bool(self.queue or self.active or self.preempted)

    def is_active(self, rid: int) -> bool:
        return rid in self._active_ids

    def covered(self) -> List[Request]:
        """Requests a worker failure can touch (the recovery rollback set):
        the running batch AND the preempted — their swap copies die with the
        failed worker too, so they must roll back with everyone else."""
        return self.active + list(self.preempted)

    # ------------------------------------------------------------------
    # policy
    # ------------------------------------------------------------------
    def plan_round(self, first_step: Callable[[Request], None]) -> StepPlan:
        """Resume / admit into freed pool space, then snapshot the round."""
        cl = self.cl
        while self.preempted and len(self.active) < self.max_active and \
                cl.can_resume(self.preempted[0].rid, len(self.active)):
            r = self.preempted.popleft()
            cl.resume_seq(r.rid)
            telemetry.count("engine.resumed")
            tracing.event("sched.resume", rid=r.rid)
            self._activate(r)
        while self.queue and len(self.active) < self.max_active and \
                cl.can_admit(self.queue[0].prompt_len, len(self.active),
                             token_ids=(self.queue[0].prompt if cl.tiered
                                        else None)):
            r = self.queue.popleft()
            # queue wait: request arrival -> admission, on the modeled clock
            wait_s = max(telemetry.clock() - r.arrival, 0.0)
            telemetry.observe("engine.queue_wait_s", wait_s)
            telemetry.count("engine.admitted")
            if tracing.active():
                tracing.event("sched.admit", rid=r.rid,
                              wait_ns=int(round(wait_s * 1e9)),
                              prompt_len=r.prompt_len)
            first_step(r)
            self._activate(r)
        if not self.active:
            # pending() held, so work exists that no pool can take
            raise MemoryError("pool cannot admit any request — "
                              "kv_pool_blocks too small for this trace")
        self.rounds += 1
        return StepPlan(round_idx=self.rounds, n_active=len(self.active),
                        work=list(self.active))

    def pick_victim(self, exclude: Iterable[int] = ()) -> Optional[Request]:
        """Preemption victim for a full pool: the YOUNGEST active sequence
        that has device-resident blocks to free.  A mid-prefill sequence
        (next_step 0) is never a victim — its chunk cursor assumes the
        partial table stays put; under swapping, sequences are offloaded
        between steps and free nothing, which the residency check covers."""
        ex = set(exclude)
        return next(
            (v for v in reversed(self.active) if v.rid not in ex
             and self.next_step[v.rid] > 0
             and self.cl.resident_blocks(v.rid) > 0), None)

    def preempt(self, victim: Request) -> None:
        """Move a (already swapped-out) victim from active to the preempted
        FIFO; `plan_round` resumes it once blocks free up."""
        self.active = [a for a in self.active if a.rid != victim.rid]
        self._active_ids.discard(victim.rid)
        self.preempted.append(victim)
        telemetry.count("engine.preemptions")
        tracing.event("sched.preempt", rid=victim.rid)

    def retire(self) -> List[Request]:
        """End of round: finished sequences return their blocks immediately
        (this is what lets the next round admit queued work)."""
        done = [r for r in self.active
                if self.next_step[r.rid] >= r.max_new or r.done]
        if done:
            gone = set()
            for r in done:
                r.done = True
                self.cl.free_seq(r.rid)
                tracing.event("sched.retire", rid=r.rid,
                              tokens=len(r.tokens))
                gone.add(r.rid)
            self.active = [a for a in self.active if a.rid not in gone]
            self._active_ids -= gone
        return done

    # ------------------------------------------------------------------
    def _activate(self, r: Request) -> None:
        self.active.append(r)
        self._active_ids.add(r.rid)
