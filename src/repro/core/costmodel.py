"""Analytic per-stage cost model (prompt Y, per-token t) for the planner,
the discrete-event simulator, and the cluster's modeled timeline.

Prompt processing is compute-bound (matmul FLOPs / peak·MFU); token
generation is bandwidth-bound (weight + KV bytes / HBM bw) — the paper's
bimodal-latency premise (§2.2.1), instantiated for TPU v5e.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig
from repro.core.dejavulib.transport import DEFAULT_HW, HardwareModel

DTYPE_BYTES = 2  # bf16


@dataclass(frozen=True)
class WorkloadSpec:
    prompt_len: int
    new_tokens: int          # mean generated tokens per microbatch
    microbatch: int


def layer_param_bytes(cfg: ArchConfig) -> float:
    """W_0 in the paper: per-layer weight bytes (active params for MoE)."""
    per_layer = cfg.active_param_count() / max(cfg.num_layers, 1)
    return per_layer * DTYPE_BYTES


def layer_prompt_kv_bytes(cfg: ArchConfig, wl: WorkloadSpec) -> float:
    """C_0: per-layer prompt KV bytes for one microbatch."""
    return (cfg.decode_state_bytes(wl.prompt_len) / max(cfg.num_layers, 1)
            * wl.microbatch)


def layer_token_kv_bytes(cfg: ArchConfig, wl: WorkloadSpec) -> float:
    """K_0: per-layer generated-token KV bytes for one microbatch."""
    return cfg.kv_bytes_per_token() / max(cfg.num_layers, 1) * wl.new_tokens * wl.microbatch


def stage_prompt_time(cfg: ArchConfig, wl: WorkloadSpec, n_layers: int,
                      chips: int, hw: HardwareModel = DEFAULT_HW,
                      mfu: float = 0.5) -> float:
    """Y per stage (seconds) — compute-bound."""
    per_layer_params = cfg.active_param_count() / max(cfg.num_layers, 1)
    tokens = wl.prompt_len * wl.microbatch
    flops = 2.0 * per_layer_params * tokens * n_layers
    if cfg.family != "ssm":
        flops += 2.0 * wl.microbatch * wl.prompt_len ** 2 * cfg.q_dim * n_layers
    return flops / (chips * hw.peak_flops * mfu)


def stage_token_time(cfg: ArchConfig, wl: WorkloadSpec, n_layers: int,
                     chips: int, context_len: int,
                     hw: HardwareModel = DEFAULT_HW, beff: float = 0.7) -> float:
    """t per stage (seconds) — HBM-bandwidth-bound (weights + KV read)."""
    w_bytes = layer_param_bytes(cfg) * n_layers
    kv_bytes = (cfg.decode_state_bytes(context_len) / max(cfg.num_layers, 1)
                * n_layers * wl.microbatch)
    return (w_bytes + kv_bytes) / (chips * hw.hbm_bw * beff)


def prompt_kv_stream_time(cfg: ArchConfig, wl: WorkloadSpec,
                          hw: HardwareModel = DEFAULT_HW) -> float:
    """Time to move one microbatch's prompt KV P→T over the network."""
    nbytes = cfg.decode_state_bytes(wl.prompt_len) * wl.microbatch
    return hw.net_latency + nbytes / hw.dcn_stream_bw


def token_kv_stream_time(cfg: ArchConfig, wl: WorkloadSpec,
                         hw: HardwareModel = DEFAULT_HW) -> float:
    """Per-step replication bytes → peer (token-level, buffered copies)."""
    nbytes = cfg.kv_bytes_per_token() * wl.microbatch
    return hw.net_latency + nbytes / hw.dcn_stream_bw


def swap_transfer_time(cfg: ArchConfig, wl: WorkloadSpec, n_layers: int,
                       context_len: int, hw: HardwareModel = DEFAULT_HW) -> float:
    """transf_i of App. E: bring one microbatch's stage KV back from host."""
    nbytes = (cfg.decode_state_bytes(context_len) / max(cfg.num_layers, 1)
              * n_layers * wl.microbatch)
    return hw.transfer_latency + nbytes / hw.host_link_bw


# ---------------------------------------------------------------------------
# chunked prefill (prefill-with-prefix-cache + chunk-interleaved scheduling)
# ---------------------------------------------------------------------------

def chunked_prefill_pass_time(cfg: ArchConfig, n_q: int, ctx: int,
                              n_layers: int, chips: int,
                              hw: HardwareModel = DEFAULT_HW,
                              mfu: float = 0.5) -> float:
    """One chunked-prefill pipeline pass: `n_q` new Q tokens ending at
    absolute context position `ctx` — compute-bound like Y.  The attention
    term is EXACT causal accounting (query at position p reads p+1 KV
    slots), so summing passes over a prompt gives the same FLOPs no matter
    how it is chunked — chunking's only modeled overhead is the per-pass
    dispatch latency `chunked_prefill_time` adds."""
    n_q = max(n_q, 0)
    per_layer_params = cfg.active_param_count() / max(cfg.num_layers, 1)
    flops = 2.0 * per_layer_params * n_q * n_layers
    if cfg.family != "ssm":
        # sum_{p=ctx-n_q..ctx-1} (p+1) = n_q*(ctx - n_q) + n_q*(n_q+1)/2
        kv_reads = n_q * max(ctx - n_q, 0) + n_q * (n_q + 1) / 2.0
        flops += 2.0 * kv_reads * cfg.q_dim * n_layers
    return flops / (chips * hw.peak_flops * mfu)


def chunked_prefill_time(cfg: ArchConfig, plen: int, chunk: int,
                         n_layers: int, chips: int,
                         hw: HardwareModel = DEFAULT_HW, mfu: float = 0.5,
                         start: int = 0) -> float:
    """Total prompt-processing time when tokens [start, plen) run in
    fixed-size chunks: the matmul/attention FLOPs equal the one-pass prefill
    of the same tokens (exact causal accounting above), plus one
    pipeline-dispatch latency per pass — the price chunking pays for
    bounding decode stalls (`chunk<=0` means one unchunked pass)."""
    chunk = chunk if chunk > 0 else max(plen - start, 1)
    total, pos = 0.0, start
    while pos < plen:
        c = min(chunk, plen - pos)
        total += chunked_prefill_pass_time(cfg, c, pos + c, n_layers, chips,
                                           hw, mfu)
        total += hw.net_latency           # per-pass stage-hop/dispatch cost
        pos += c
    return total


def prefill_stall_time(cfg: ArchConfig, wl: WorkloadSpec, chunk: int,
                       n_layers: int, chips: int,
                       hw: HardwareModel = DEFAULT_HW,
                       mfu: float = 0.5) -> float:
    """Longest a co-scheduled decode step waits behind an in-flight prompt
    pass: the final chunk (worst context) of every prompt in the microbatch
    with interleaving, the whole prompt without."""
    n_q = (min(chunk, wl.prompt_len) if chunk > 0 else wl.prompt_len)
    return wl.microbatch * chunked_prefill_pass_time(
        cfg, n_q, wl.prompt_len, n_layers, chips, hw, mfu)


def prefill_bubble_frac(cfg: ArchConfig, wl: WorkloadSpec, chunk: int,
                        n_layers: int, chips: int, ctx: int,
                        hw: HardwareModel = DEFAULT_HW, mfu: float = 0.5,
                        beff: float = 0.7) -> float:
    """Fraction of a co-scheduled decode round occupied by an in-flight
    prefill pass (the pipeline 'bubble' a decode step waits out), computed
    from the SAME stall `prefill_stall_time` reports.  In [0, 1)."""
    stall = prefill_stall_time(cfg, wl, chunk, n_layers, chips, hw, mfu)
    t = stage_token_time(cfg, wl, n_layers, chips, ctx, hw, beff)
    return stall / max(stall + t, 1e-30)


# ---------------------------------------------------------------------------
# fused batched rounds (continuous batching: ONE pipeline pass per round)
# ---------------------------------------------------------------------------

def fused_round_supported(cfg: ArchConfig) -> bool:
    """Whether the engine's fused batched round path serves this config —
    the cost-model mirror of the cluster gate (`cluster.fused_supported`):
    every dense/moe attention variant (full-causal, ALiBi, sliding-window
    +meta) fuses; ssm/hybrid/encdec recurrence and vlm patch slots run
    per-sequence."""
    return cfg.family in ("dense", "moe") and not cfg.num_patches


def decode_round_time(cfg: ArchConfig, n_active: int, ctx: int,
                      n_layers: int, chips: int,
                      hw: HardwareModel = DEFAULT_HW, beff: float = 0.7,
                      *, fused: bool = True) -> float:
    """Modeled wall time of ONE continuous-batching decode round with
    `n_active` live sequences at mean context `ctx`.

    Fused: one bandwidth-bound pass reads the stage weights ONCE plus every
    sequence's KV, plus a single dispatch latency — round time is O(1) in
    pass count and grows only with the aggregate KV bytes.  Per-sequence
    (the oracle path): one pass per live sequence, each pass re-reading the
    full stage weights and paying its own dispatch latency — exactly the
    O(n_active) round the fused refactor removes.  Both sides are built from
    the SAME `stage_token_time` term, so their ratio isolates the
    weight-re-read + dispatch overhead.

    `fused=True` degrades to the per-sequence time for families the engine
    cannot fuse (`fused_round_supported`), so planner round terms reflect
    the path the engine will actually take."""
    fused = fused and fused_round_supported(cfg)
    wl1 = WorkloadSpec(prompt_len=ctx, new_tokens=1, microbatch=1)
    one = stage_token_time(cfg, wl1, n_layers, chips, ctx, hw, beff)
    if not fused:
        return n_active * (one + hw.net_latency)
    wlb = WorkloadSpec(prompt_len=ctx, new_tokens=1, microbatch=n_active)
    return (stage_token_time(cfg, wlb, n_layers, chips, ctx, hw, beff)
            + hw.net_latency)


# ---------------------------------------------------------------------------
# tiered KV-cache hierarchy (HBM -> host -> SSD; repro.kvcache.tiers)
# ---------------------------------------------------------------------------

def kv_block_bytes(cfg: ArchConfig, dtype_bytes: int = DTYPE_BYTES) -> float:
    """Bytes of one whole-model KV block (`kv_block_size` token slots)."""
    return cfg.decode_state_bytes(cfg.kv_block_size, dtype_bytes)


def promotion_time(cfg: ArchConfig, n_blocks: float, src_tier: int,
                   hw: HardwareModel = DEFAULT_HW) -> float:
    """Time to bring `n_blocks` KV blocks back into HBM from `src_tier`
    (1 = host RAM over the host link; 2 = SSD read, then the host link)."""
    nbytes = n_blocks * kv_block_bytes(cfg)
    t = hw.transfer_latency + nbytes / hw.host_link_bw
    if src_tier >= 2:
        t += hw.transfer_latency + nbytes / hw.ssd_bw
    return t


def write_behind_time(cfg: ArchConfig, n_blocks: float, dst_tier: int,
                      hw: HardwareModel = DEFAULT_HW) -> float:
    """Time to demote `n_blocks` KV blocks down to `dst_tier`.  Run as
    write-behind on the streaming thread, this is HIDDEN whenever per-step
    compute exceeds it (the `StreamEngine` overlap report measures the
    remainder)."""
    nbytes = n_blocks * kv_block_bytes(cfg)
    t = hw.transfer_latency + nbytes / hw.host_link_bw
    if dst_tier >= 2:
        t += hw.transfer_latency + nbytes / hw.ssd_bw
    return t


def prefix_reuse_prefill_time(cfg: ArchConfig, wl: WorkloadSpec,
                              base_y: float, hit_frac: float, src_tier: int,
                              hw: HardwareModel = DEFAULT_HW,
                              n_stages: int = 1) -> float:
    """Effective prompt time when `hit_frac` of each prompt is served by
    cross-request prefix hits: that fraction of prefill compute is replaced
    by promoting the matching blocks.  Each of the `n_stages` pipeline
    stages promotes only its own layer slice, concurrently over its own
    host link.  Only the chain head's latency is truly exposed — the rest
    prefetches behind the suffix compute — so charging the full per-stage
    promotion time keeps this an upper bound."""
    hit_frac = min(max(hit_frac, 0.0), 1.0)
    n_blocks = (hit_frac * wl.prompt_len / max(cfg.kv_block_size, 1)
                * wl.microbatch / max(n_stages, 1))
    return base_y * (1.0 - hit_frac) + promotion_time(cfg, n_blocks, src_tier, hw)
