"""Analytic per-stage cost model (prompt Y, per-token t) for the planner,
the discrete-event simulator, and the cluster's modeled timeline.

Prompt processing is compute-bound (matmul FLOPs / peak·MFU); token
generation is bandwidth-bound (weight + KV bytes / HBM bw) — the paper's
bimodal-latency premise (§2.2.1), instantiated for TPU v5e.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig
from repro.core.dejavulib.transport import HardwareModel, DEFAULT_HW

DTYPE_BYTES = 2  # bf16


@dataclass(frozen=True)
class WorkloadSpec:
    prompt_len: int
    new_tokens: int          # mean generated tokens per microbatch
    microbatch: int


def layer_param_bytes(cfg: ArchConfig) -> float:
    """W_0 in the paper: per-layer weight bytes (active params for MoE)."""
    per_layer = cfg.active_param_count() / max(cfg.num_layers, 1)
    return per_layer * DTYPE_BYTES


def layer_prompt_kv_bytes(cfg: ArchConfig, wl: WorkloadSpec) -> float:
    """C_0: per-layer prompt KV bytes for one microbatch."""
    return (cfg.decode_state_bytes(wl.prompt_len) / max(cfg.num_layers, 1)
            * wl.microbatch)


def layer_token_kv_bytes(cfg: ArchConfig, wl: WorkloadSpec) -> float:
    """K_0: per-layer generated-token KV bytes for one microbatch."""
    return cfg.kv_bytes_per_token() / max(cfg.num_layers, 1) * wl.new_tokens * wl.microbatch


def stage_prompt_time(cfg: ArchConfig, wl: WorkloadSpec, n_layers: int,
                      chips: int, hw: HardwareModel = DEFAULT_HW,
                      mfu: float = 0.5) -> float:
    """Y per stage (seconds) — compute-bound."""
    per_layer_params = cfg.active_param_count() / max(cfg.num_layers, 1)
    tokens = wl.prompt_len * wl.microbatch
    flops = 2.0 * per_layer_params * tokens * n_layers
    if cfg.family != "ssm":
        flops += 2.0 * wl.microbatch * wl.prompt_len ** 2 * cfg.q_dim * n_layers
    return flops / (chips * hw.peak_flops * mfu)


def stage_token_time(cfg: ArchConfig, wl: WorkloadSpec, n_layers: int,
                     chips: int, context_len: int,
                     hw: HardwareModel = DEFAULT_HW, beff: float = 0.7) -> float:
    """t per stage (seconds) — HBM-bandwidth-bound (weights + KV read)."""
    w_bytes = layer_param_bytes(cfg) * n_layers
    kv_bytes = (cfg.decode_state_bytes(context_len) / max(cfg.num_layers, 1)
                * n_layers * wl.microbatch)
    return (w_bytes + kv_bytes) / (chips * hw.hbm_bw * beff)


def prompt_kv_stream_time(cfg: ArchConfig, wl: WorkloadSpec,
                          hw: HardwareModel = DEFAULT_HW) -> float:
    """Time to move one microbatch's prompt KV P→T over the network."""
    nbytes = cfg.decode_state_bytes(wl.prompt_len) * wl.microbatch
    return hw.net_latency + nbytes / hw.dcn_stream_bw


def token_kv_stream_time(cfg: ArchConfig, wl: WorkloadSpec,
                         hw: HardwareModel = DEFAULT_HW) -> float:
    """Per-step replication bytes → peer (token-level, buffered copies)."""
    nbytes = cfg.kv_bytes_per_token() * wl.microbatch
    return hw.net_latency + nbytes / hw.dcn_stream_bw


def swap_transfer_time(cfg: ArchConfig, wl: WorkloadSpec, n_layers: int,
                       context_len: int, hw: HardwareModel = DEFAULT_HW) -> float:
    """transf_i of App. E: bring one microbatch's stage KV back from host."""
    nbytes = (cfg.decode_state_bytes(context_len) / max(cfg.num_layers, 1)
              * n_layers * wl.microbatch)
    return hw.transfer_latency + nbytes / hw.host_link_bw
