"""DéjàVu resource-allocation planner (paper §4.2.1, Eqs. 1–6).

Given D machines (each: `chips` accelerators, M bytes aggregate device
memory), partition them into a prompt pipeline (depth D_p) and a token
pipeline (depth D_t = D − D_p) such that

  (1) memory feasibility:  D_p ≥ ⌈L·(C0+W0)/M⌉            (Eq. 1)
                           D_t ≥ L·W0 / (M − L·(C0+K0))    (Eq. 2)
  (2) throughput:          minimize I_dis = max(I_t, I_p); the continuous
      optimum is D_t = D·N·t/(m·Y + N·t) (Eq. 5); disaggregation wins iff
      Y/t > (D−1)/(D·(2−m)−1) with m ∈ [1,2) (Eq. 4).

The integer split searches around the continuous optimum subject to (1).
`m` (prompt-streaming overhead factor) is derived from the transport model
instead of being guessed — DéjàVuLib's layer-wise overlap hides streaming
behind the NEXT microbatch's prompt compute, so only the non-hidden
remainder inflates m.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ArchConfig
from repro.core import costmodel as cm
from repro.core.dejavulib.transport import DEFAULT_HW, HardwareModel


@dataclass(frozen=True)
class MachineSpec:
    """One 'machine' = one pipeline stage = a v5e host (8 chips TP inside,
    the ICI-connected analogue of the paper's 2×A100 VM)."""
    chips: int = 8
    mem_bytes: float = 8 * 16e9      # aggregate device HBM per machine


@dataclass(frozen=True)
class TierSpec:
    """Off-device KV tiers available to each token-pipeline stage (see
    `repro.kvcache.tiers.KVTierManager`): cold blocks spill to host RAM and
    SSD, so only a working-set fraction of the generated-token KV must stay
    resident in HBM."""
    host_blocks: int = 0             # host-RAM tier capacity per stage
    ssd_blocks: int = 0              # SSD tier capacity per stage
    min_resident_frac: float = 0.25  # working set that must stay in HBM


@dataclass
class Plan:
    d: int
    d_prompt: int
    d_token: int
    feasible: bool
    disagg_beneficial: bool
    m_overhead: float
    inv_tp_colocated: float      # I_c  (s per microbatch completion)
    inv_tp_disagg: float         # I_dis
    prompt_stage_time: float     # Y_dis / D_p
    token_stage_time: float      # t_dis / D_t
    # colocated decode-stall bound: the longest a decode step can wait behind
    # an in-flight prompt pass (one chunk with chunk-interleaving, the whole
    # prompt without) and the bubble fraction of a decode round it implies
    decode_stall_s: float = 0.0
    bubble_frac: float = 0.0
    # continuous-batching round time at `microbatch` live sequences: one pass
    # per sequence (oracle path) vs ONE fused batched pass per round — both
    # derived from the same stage_token_time term (cm.decode_round_time).
    # For families the engine cannot fuse (cm.fused_round_supported) the
    # fused term equals the per-seq term, so fused_round_speedup reads 1.0
    round_time_perseq_s: float = 0.0
    round_time_fused_s: float = 0.0
    note: str = ""

    @property
    def speedup(self) -> float:
        return self.inv_tp_colocated / self.inv_tp_disagg if self.inv_tp_disagg else 0.0

    @property
    def fused_round_speedup(self) -> float:
        return (self.round_time_perseq_s / self.round_time_fused_s
                if self.round_time_fused_s else 0.0)


def paged_token_kv_bytes(cfg: ArchConfig, wl: cm.WorkloadSpec,
                         kv_util: float = 0.5) -> float:
    """K_0 under the paged pool: continuous batching keeps only the LIVE
    prefix of each request's growth window resident (mean occupancy
    `kv_util` of `new_tokens`, ~0.5 for arrival-mixed traces since retired
    requests free immediately), plus at most one partially-filled block per
    sequence of internal fragmentation."""
    k0 = cm.layer_token_kv_bytes(cfg, wl) * kv_util
    slack = (0.5 * cfg.kv_block_size * cfg.kv_bytes_per_token()
             / max(cfg.num_layers, 1) * wl.microbatch)
    return k0 + slack


def min_prompt_depth(cfg: ArchConfig, wl: cm.WorkloadSpec, mach: MachineSpec) -> int:
    w0 = cm.layer_param_bytes(cfg)
    c0 = cm.layer_prompt_kv_bytes(cfg, wl)
    return max(1, math.ceil(cfg.num_layers * (c0 + w0) / mach.mem_bytes))


def tiered_token_kv_bytes(cfg: ArchConfig, wl: cm.WorkloadSpec,
                          tiers: TierSpec, kv_util: float = 0.5) -> float:
    """K_0 with the tier hierarchy behind the pool: host/SSD-backed blocks
    absorb the cold tail of the live KV, so HBM only needs the hot working
    set (floored at `min_resident_frac` — promotion latency makes an
    all-cold pool useless)."""
    k0 = paged_token_kv_bytes(cfg, wl, kv_util)
    backed = ((tiers.host_blocks + tiers.ssd_blocks) * cm.kv_block_bytes(cfg)
              / max(cfg.num_layers, 1))
    return max(k0 - backed, k0 * tiers.min_resident_frac)


def min_token_depth(cfg: ArchConfig, wl: cm.WorkloadSpec, mach: MachineSpec,
                    *, paged: bool = False, kv_util: float = 0.5,
                    tiers: Optional[TierSpec] = None) -> int:
    w0 = cm.layer_param_bytes(cfg)
    c0 = cm.layer_prompt_kv_bytes(cfg, wl)
    if tiers is not None:
        k0 = tiered_token_kv_bytes(cfg, wl, tiers, kv_util)
    elif paged:
        k0 = paged_token_kv_bytes(cfg, wl, kv_util)
    else:
        k0 = cm.layer_token_kv_bytes(cfg, wl)
    denom = mach.mem_bytes - cfg.num_layers * (c0 + k0)
    if denom <= 0:
        return -1  # even one stage per layer can't hold the KV — infeasible
    return max(1, math.ceil(cfg.num_layers * w0 / denom))


def colocated_inverse_throughput(d: int, y: float, t: float, n: int) -> float:
    """Eq. 3: I_c = (D−1)(Y−t)/D + Y + N·t  (per-microbatch steady state)."""
    return (d - 1) * (y - t) / d + y + n * t


def estimate_m(cfg: ArchConfig, wl: cm.WorkloadSpec, y_total: float, dp: int,
               mach: MachineSpec, hw: HardwareModel) -> float:
    """Prompt-stream overhead factor m ≥ 1 for a prompt pipeline of depth dp.

    P→T streaming rides intra-pod ICI (both pipelines live on the same mesh),
    drained by a background thread layer-by-layer while the stage prefills the
    NEXT microbatch (paper §4.1 opt-2).  The stage only stalls (inflating m)
    when its per-microbatch KV production outruns its aggregate ICI egress
    during one steady-state prompt slot; a ~2% residual (paper App. D)
    accounts for pack-kernel + dispatch overheads."""
    kv_per_stage = cfg.decode_state_bytes(wl.prompt_len) * wl.microbatch / dp
    window = y_total * 1.0 / dp          # stage busy-time per microbatch slot
    egress_bw = hw.ici_bw * mach.chips   # one link per chip toward the T-group
    drain = kv_per_stage / egress_bw
    exposed = max(0.0, drain - window)
    m = 1.02 + exposed / max(window, 1e-9)
    return min(max(m, 1.0), 2.5)


def plan(cfg: ArchConfig, wl: cm.WorkloadSpec, d: int,
         mach: MachineSpec = MachineSpec(), hw: HardwareModel = DEFAULT_HW,
         mfu: float = 0.5, beff: float = 0.7, *, paged: bool = False,
         kv_util: float = 0.5, tiers: Optional[TierSpec] = None,
         prefix_hit_rate: float = 0.0, prefix_src_tier: int = 1,
         prefill_chunk_tokens: int = 0) -> Plan:
    """`paged=True` plans against the paged pool's live-block footprint
    (continuous batching) instead of the static prompt+new reservation —
    the same D often becomes feasible at larger microbatches.

    `tiers` additionally credits host/SSD-backed capacity against the
    token-side HBM requirement (Eq. 2's K_0 shrinks to the hot working set),
    and `prefix_hit_rate` models cross-request prefix reuse: that fraction
    of every prompt is served by promoting cached blocks from
    `prefix_src_tier` instead of prefill compute.

    `prefill_chunk_tokens` (0 = no chunking) bounds the colocated
    decode-stall: with chunk-interleaved scheduling a decode step waits at
    most one chunk pass of a co-scheduled prompt, not the whole prompt —
    reported as `Plan.decode_stall_s` / `Plan.bubble_frac`."""
    l = cfg.num_layers
    ctx = wl.prompt_len + wl.new_tokens
    # whole-model times with all D machines (the paper's Y and t)
    y = cm.stage_prompt_time(cfg, wl, l, d * mach.chips, hw, mfu)
    t = cm.stage_token_time(cfg, wl, l, d * mach.chips, ctx, hw, beff)
    n = wl.new_tokens
    ic = colocated_inverse_throughput(d, y, t, n)
    stall = cm.prefill_stall_time(cfg, wl, prefill_chunk_tokens, l,
                                  d * mach.chips, hw, mfu)
    bubble = cm.prefill_bubble_frac(cfg, wl, prefill_chunk_tokens, l,
                                    d * mach.chips, ctx, hw, mfu, beff)
    rt_seq = cm.decode_round_time(cfg, wl.microbatch, ctx, l, d * mach.chips,
                                  hw, beff, fused=False)
    rt_fused = cm.decode_round_time(cfg, wl.microbatch, ctx, l,
                                    d * mach.chips, hw, beff, fused=True)

    dp_min = min_prompt_depth(cfg, wl, mach)
    dt_min = min_token_depth(cfg, wl, mach, paged=paged, kv_util=kv_util,
                             tiers=tiers)
    if dt_min < 0 or dp_min + max(dt_min, 1) > d:
        return Plan(d, 0, 0, False, False, 1.0, ic, float("inf"), 0, 0,
                    decode_stall_s=stall, bubble_frac=bubble,
                    round_time_perseq_s=rt_seq, round_time_fused_s=rt_fused,
                    note="memory-infeasible for this D")

    # continuous optimum (Eq. 5) then integer search subject to Eqs. 1–2;
    # m depends on the prompt depth, so it is evaluated per candidate split
    best: Optional[Plan] = None
    for dt in range(max(dt_min, 1), d - dp_min + 1):
        dp = d - dt
        m = estimate_m(cfg, wl, y, dp, mach, hw)
        y_dis = y * d / dp           # fewer machines → slower prompt
        if prefix_hit_rate > 0:
            y_dis = cm.prefix_reuse_prefill_time(cfg, wl, y_dis,
                                                 prefix_hit_rate,
                                                 prefix_src_tier, hw,
                                                 n_stages=dp)
        t_dis = t * d / dt
        # steady-state per-microbatch slot of each pipeline
        i_p = m * y_dis
        i_t = n * t_dis
        i_dis = max(i_p, i_t)
        cand = Plan(d, dp, dt, True, i_dis < ic, m, ic, i_dis,
                    y_dis / dp, t_dis / dt,
                    decode_stall_s=stall, bubble_frac=bubble,
                    round_time_perseq_s=rt_seq, round_time_fused_s=rt_fused)
        if best is None or cand.inv_tp_disagg < best.inv_tp_disagg:
            best = cand
    assert best is not None
    # Eq. 4 sanity check (continuous-form benefit condition)
    denom = d * (2 - best.m_overhead) - 1
    cond = (y / t) > ((d - 1) / denom) if denom > 0 else False
    best.note = f"eq4_benefit_condition={cond}"
    return best


def replan_after_failure(current: Plan, cfg: ArchConfig, wl: cm.WorkloadSpec,
                         d_new: int, **kw) -> Plan:
    """Elastic re-planning when workers join/leave (beyond-paper feature)."""
    return plan(cfg, wl, d_new, **kw)
