"""Wire-format exporters for telemetry snapshots and trace dumps.

Three formats, all pure functions over the versioned snapshot dicts so
they can run offline over archived JSON as well as live registries:

- :func:`telemetry_to_prometheus` — Prometheus text exposition
  (format 0.0.4) for a ``repro.telemetry/v1`` snapshot.
- :func:`trace_to_perfetto` — Chrome/Perfetto ``trace_event`` JSON for a
  ``repro.trace/v1`` dump; loads directly in https://ui.perfetto.dev
  with one named thread-track per recorder track and instant events for
  faults.
- :func:`trace_to_otlp` — OTLP-JSON (``ExportTraceServiceRequest``
  shape: resourceSpans → scopeSpans → spans) for the same dump, with
  requests mapped to trace IDs and causal parents to ``parentSpanId``.

All output is deterministic: keys sorted, label sets sorted, tracks in
snapshot order (which is itself sorted).
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

from repro.core import telemetry as _telemetry
from repro.core import tracing as _tracing

_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _prom_name(name: str) -> str:
    out = _NAME_BAD.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _prom_escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace("\"", "\\\"").replace("\n", "\\n")


def _split_label_key(key: str) -> Tuple[str, List[Tuple[str, str]]]:
    """Invert telemetry's ``name{k=v,k2=v2}`` label-key encoding."""
    if "{" not in key:
        return key, []
    name, rest = key.split("{", 1)
    rest = rest.rstrip("}")
    labels = []
    for part in rest.split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        labels.append((k, v))
    return name, labels


def _prom_labels(labels: List[Tuple[str, str]]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_prom_name(k)}="{_prom_escape(v)}"'
                     for k, v in sorted(labels))
    return "{" + inner + "}"


def _fmt_num(v: float) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


def telemetry_to_prometheus(snapshot: Dict[str, object]) -> str:
    """Render a ``repro.telemetry/v1`` snapshot as Prometheus text.

    Counters get a ``_total`` suffix; histograms expand to cumulative
    ``_bucket{le=...}`` series plus ``_sum``/``_count``; span aggregates
    become ``span_count`` / ``span_total_seconds`` / ``span_max_seconds``
    with the span path as a label.  Output is sorted and ends with a
    newline, per the exposition format.
    """
    schema = snapshot.get("schema")
    if schema != _telemetry.SCHEMA:
        raise ValueError(f"expected {_telemetry.SCHEMA} snapshot, got {schema!r}")
    lines: List[str] = []

    # group metric rows by base name so TYPE headers aren't repeated
    families: Dict[str, Tuple[str, List[str]]] = {}

    def add(base: str, mtype: str, row: str) -> None:
        fam = families.setdefault(base, (mtype, []))
        fam[1].append(row)

    for key in sorted(snapshot.get("counters", {})):
        name, labels = _split_label_key(key)
        base = _prom_name(name) + "_total"
        v = snapshot["counters"][key]
        add(base, "counter", f"{base}{_prom_labels(labels)} {_fmt_num(v)}")

    for key in sorted(snapshot.get("gauges", {})):
        name, labels = _split_label_key(key)
        base = _prom_name(name)
        v = snapshot["gauges"][key]
        add(base, "gauge", f"{base}{_prom_labels(labels)} {_fmt_num(v)}")

    for key in sorted(snapshot.get("histograms", {})):
        name, labels = _split_label_key(key)
        base = _prom_name(name)
        h = snapshot["histograms"][key]
        cum = 0
        for edge, n in zip(h["buckets_s"], h["counts"]):
            cum += n
            le = sorted(labels) + [("le", _fmt_num(float(edge)))]
            add(base, "histogram",
                f"{base}_bucket{_prom_labels(le)} {cum}")
        le = sorted(labels) + [("le", "+Inf")]   # includes the overflow bucket
        add(base, "histogram",
            f"{base}_bucket{_prom_labels(le)} {h['count']}")
        add(base, "histogram",
            f"{base}_sum{_prom_labels(labels)} {_fmt_num(float(h['sum_s']))}")
        add(base, "histogram",
            f"{base}_count{_prom_labels(labels)} {h['count']}")

    for key in sorted(snapshot.get("spans", {})):
        s = snapshot["spans"][key]
        labels = [("path", key)]
        add("span_count", "counter",
            f"span_count{_prom_labels(labels)} {s['count']}")
        add("span_total_seconds", "counter",
            f"span_total_seconds{_prom_labels(labels)} "
            f"{_fmt_num(float(s['total_s']))}")
        add("span_max_seconds", "gauge",
            f"span_max_seconds{_prom_labels(labels)} "
            f"{_fmt_num(float(s['max_s']))}")

    add("modeled_clock_seconds", "gauge",
        f"modeled_clock_seconds {_fmt_num(float(snapshot.get('clock_s', 0.0)))}")

    for base in sorted(families):
        mtype, rows = families[base]
        lines.append(f"# TYPE {base} {mtype}")
        lines.extend(rows)
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Chrome / Perfetto trace_event JSON
# ---------------------------------------------------------------------------

def _track_order(tracks: Dict[str, object]) -> List[str]:
    """serve first, streamer last, worker/stage tracks in between sorted."""
    names = list(tracks)
    def rank(n: str) -> Tuple[int, str]:
        if n == _tracing.SERVE_TRACK:
            return (0, n)
        if n == _tracing.STREAM_TRACK:
            return (2, n)
        return (1, n)
    return sorted(names, key=rank)


def trace_to_perfetto(trace: Dict[str, object]) -> Dict[str, object]:
    """Convert a ``repro.trace/v1`` dump to Chrome ``trace_event`` JSON.

    One pid, one tid per recorder track (named via ``M``/``thread_name``
    metadata).  Span events ("X") carry ``ts``/``dur`` in microseconds
    (floats, so sub-µs modeled durations survive); instants become
    ``ph: "i"`` with thread scope.  Fault events keep their ``fault.``
    name prefix so they are findable in the Perfetto query bar.
    """
    schema = trace.get("schema")
    if schema != _tracing.SCHEMA:
        raise ValueError(f"expected {_tracing.SCHEMA} dump, got {schema!r}")
    tracks = trace.get("tracks", {})
    order = _track_order(tracks)
    events: List[dict] = []
    pid = 1
    for tid, name in enumerate(order, start=1):
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": name}})
    for tid, tname in enumerate(order, start=1):
        tr = tracks[tname]
        for ev in tr["events"]:
            args = dict(ev.get("args", {}))
            if "rid" in ev:
                args["rid"] = ev["rid"]
            if "seq" in ev:
                args["seq"] = ev["seq"]
            out = {"name": ev["name"], "pid": pid, "tid": tid,
                   "ts": ev["ts"] / 1000.0}
            if args:
                out["args"] = {k: args[k] for k in sorted(args)}
            if ev["ph"] == "X":
                out["ph"] = "X"
                out["dur"] = ev.get("dur", 0) / 1000.0
            else:
                out["ph"] = "i"
                out["s"] = "t"
            events.append(out)
        if tr.get("dropped"):
            events.append({"ph": "i", "s": "t", "name": "trace.dropped",
                           "pid": pid, "tid": tid, "ts": 0.0,
                           "args": {"dropped": tr["dropped"]}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# OTLP-JSON spans
# ---------------------------------------------------------------------------

def _otlp_attr(k: str, v: object) -> dict:
    if isinstance(v, bool):
        val = {"boolValue": v}
    elif isinstance(v, int):
        val = {"intValue": str(v)}
    elif isinstance(v, float):
        val = {"doubleValue": v}
    else:
        val = {"stringValue": str(v)}
    return {"key": k, "value": val}


def _trace_id(rid: Optional[int]) -> str:
    # one trace per request; rid-less events share the run-level trace 0
    return format(0 if rid is None else int(rid) + 1, "032x")


def _span_id(track_idx: int, eid: int) -> str:
    return format(((track_idx + 1) << 40) | (eid + 1), "016x")


def trace_to_otlp(trace: Dict[str, object],
                  service_name: str = "dejavu-repro") -> Dict[str, object]:
    """Convert a ``repro.trace/v1`` dump to an OTLP-JSON
    ``ExportTraceServiceRequest`` document.

    Each request ID becomes its own 128-bit trace ID (rid-less events
    share trace 0); span IDs encode (track, eid) so causal ``parent``
    links resolve to ``parentSpanId`` within the serve track.  Instant
    events export as zero-length spans, which every OTLP backend
    accepts.
    """
    schema = trace.get("schema")
    if schema != _tracing.SCHEMA:
        raise ValueError(f"expected {_tracing.SCHEMA} dump, got {schema!r}")
    tracks = trace.get("tracks", {})
    order = _track_order(tracks)
    # `parent` eids always reference the serve track (spans live there)
    serve_ti = order.index(_tracing.SERVE_TRACK) if _tracing.SERVE_TRACK in order else 0
    spans: List[dict] = []
    for ti, tname in enumerate(order):
        tr = tracks[tname]
        for ev in tr["events"]:
            rid = ev.get("rid")
            start = int(ev["ts"])
            end = start + int(ev.get("dur", 0))
            attrs = [_otlp_attr("track", tname)]
            if "seq" in ev:
                attrs.append(_otlp_attr("seq", ev["seq"]))
            for k in sorted(ev.get("args", {})):
                attrs.append(_otlp_attr(k, ev["args"][k]))
            span = {
                "traceId": _trace_id(rid),
                "spanId": _span_id(ti, ev["eid"]),
                "name": ev["name"],
                "kind": 1,  # SPAN_KIND_INTERNAL
                "startTimeUnixNano": str(start),
                "endTimeUnixNano": str(end),
                "attributes": attrs,
            }
            if ev.get("parent") is not None:
                span["parentSpanId"] = _span_id(serve_ti, ev["parent"])
            spans.append(span)
    return {
        "resourceSpans": [{
            "resource": {"attributes": [
                _otlp_attr("service.name", service_name),
            ]},
            "scopeSpans": [{
                "scope": {"name": "repro.tracing", "version": "1"},
                "spans": spans,
            }],
        }],
    }


def dumps(doc: Dict[str, object]) -> str:
    """Canonical JSON serialisation shared by exporter CLI/test paths."""
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))
