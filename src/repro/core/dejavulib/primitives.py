"""DéjàVuLib primitives (paper §4.1.2, Table 1).

Layered exactly as in the paper:

  stream_out / stream_in   top level — given source/destination pipeline
                           topologies (depths, microbatch sizes), plan which
                           chunks of the stacked decode state go to which
                           peer (splitting at the source / merging at the
                           destination) and move them;
  scatter / gather         middle — turn a non-contiguous region of the
                           cache into contiguous transfers (the Pallas
                           `kv_pack` kernel implements the paper's
                           "buffered copies" optimization) and orchestrate
                           movement;
  flush / fetch            bottom — one contiguous chunk, local or remote
                           (CUDA/NCCL/MPI in the paper → host-link / ICI /
                           DCN transports here).

Decode-state leaves are addressed by path; leaves shaped [L,B,S,...] are
partitionable over layers/batch/tokens, [L,B,...] over layers/batch, and
1-D metadata leaves are replicated.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dejavulib.buffers import HostMemoryStore
from repro.core.dejavulib.transport import Transport

# leaf classification: token axis position (None = no token axis)
TOKEN_AXIS = 2


@dataclass(frozen=True)
class PipelineTopo:
    """A pipeline's shape: `depth` stages over `num_layers`, `microbatch`."""
    depth: int
    num_layers: int
    microbatch: int

    def layer_range(self, stage: int) -> Tuple[int, int]:
        splits = np.array_split(np.arange(self.num_layers), self.depth)
        seg = splits[stage]
        return (int(seg[0]), int(seg[-1]) + 1) if len(seg) else (0, 0)

    def stage_of_layer(self, layer: int) -> int:
        for s in range(self.depth):
            lo, hi = self.layer_range(s)
            if lo <= layer < hi:
                return s
        raise ValueError(layer)


@dataclass(frozen=True)
class CacheChunk:
    """A rectangular region of one decode-state leaf."""
    leaf: str
    layers: Tuple[int, int]
    batch: Tuple[int, int]
    tokens: Optional[Tuple[int, int]] = None   # None = leaf has no token axis

    def key(self, mb: int | str) -> str:
        t = f"/t{self.tokens[0]}-{self.tokens[1]}" if self.tokens else ""
        return (f"mb{mb}/{self.leaf}/l{self.layers[0]}-{self.layers[1]}"
                f"/b{self.batch[0]}-{self.batch[1]}{t}")


def _overlap(a: Tuple[int, int], b: Tuple[int, int]) -> Optional[Tuple[int, int]]:
    lo, hi = max(a[0], b[0]), min(a[1], b[1])
    return (lo, hi) if lo < hi else None


def plan_repartition(src: PipelineTopo, dst: PipelineTopo
                     ) -> List[Tuple[int, int, Tuple[int, int], Tuple[int, int]]]:
    """All (src_stage, dst_stage, layer_range, batch_range) intersections.

    Handles differing pipeline depths (layer split/merge) AND differing
    microbatch sizes (batch split/merge) — the paper's stream_out contract.
    """
    assert src.num_layers == dst.num_layers
    plan = []
    nb = max(src.microbatch, dst.microbatch)
    src_b = [(i * src.microbatch, (i + 1) * src.microbatch)
             for i in range(max(1, nb // src.microbatch))]
    dst_b = [(j * dst.microbatch, (j + 1) * dst.microbatch)
             for j in range(max(1, nb // dst.microbatch))]
    for ss in range(src.depth):
        sl = src.layer_range(ss)
        for ds in range(dst.depth):
            dl = dst.layer_range(ds)
            lr = _overlap(sl, dl)
            if lr is None:
                continue
            for sb in src_b:
                for db in dst_b:
                    br = _overlap(sb, db)
                    if br is not None:
                        plan.append((ss, ds, lr, br))
    return plan


# ---------------------------------------------------------------------------
# flush / fetch — one contiguous chunk
# ---------------------------------------------------------------------------

def flush(array, store, key: str, transport: Transport, *, tag: str = "",
          n_messages: int = 1) -> int:
    """Copy one contiguous chunk to a (possibly remote) store."""
    arr = np.asarray(array)
    out = transport.transfer(arr, tag=tag or key, n_messages=n_messages)
    store.put(key, out)
    return out.nbytes


def fetch(store, key: str, transport: Transport, *, tag: str = "") -> np.ndarray:
    arr = store.get(key)
    return transport.transfer(arr, tag=tag or key)


# ---------------------------------------------------------------------------
# scatter / gather — non-contiguous regions -> contiguous transfers
# ---------------------------------------------------------------------------

def scatter(cache_leaf, leaf_name: str, token_range: Tuple[int, int],
            store, transport: Transport, *, mb: int | str = 0,
            buffered: bool = True, token_block: int = 8) -> Dict[str, int]:
    """Stream the token window `token_range` of a stacked leaf [L,B,S,H,D].

    buffered=True (paper opt-1): one `kv_pack` Pallas launch packs the
    window across all layers into a single contiguous buffer → ONE transfer.
    buffered=False (paper's baseline): one transfer per (layer, k/v slice),
    each paying the per-message latency — used by the Fig.-11 benchmark.
    """
    t0, t1 = token_range
    width = t1 - t0
    l = cache_leaf.shape[0]
    chunk = CacheChunk(leaf_name, (0, l), (0, cache_leaf.shape[1]), (t0, t1))
    key = chunk.key(mb)
    if buffered:
        from repro.kernels import ops as kops
        t0a = (t0 // token_block) * token_block           # DMA alignment
        w = ((t1 - t0a + token_block - 1) // token_block) * token_block
        w = min(w, cache_leaf.shape[TOKEN_AXIS] - t0a)
        buf = kops.kv_pack_auto(cache_leaf, t0a, w, token_block=token_block)
        buf = np.asarray(buf)[:, :, t0 - t0a: t0 - t0a + width]
        nbytes = flush(buf, store, key, transport, n_messages=1)
        return {key: nbytes}
    # baseline: per-layer small copies (L messages, each with latency)
    out: Dict[str, int] = {}
    arr = np.asarray(cache_leaf)
    for li in range(l):
        k = CacheChunk(leaf_name, (li, li + 1), (0, arr.shape[1]), (t0, t1)).key(mb)
        out[k] = flush(arr[li: li + 1, :, t0:t1], store, k, transport, n_messages=1)
    return out


def gather(store, leaf_name: str, shape, dtype, chunks: Sequence[CacheChunk],
           transport: Transport, *, mb: int | str = 0) -> np.ndarray:
    """Assemble chunks (fetched from `store`) into a dense leaf array."""
    out = np.zeros(shape, dtype)
    for ch in chunks:
        arr = fetch(store, ch.key(mb), transport)
        sl = [slice(ch.layers[0], ch.layers[1]), slice(ch.batch[0], ch.batch[1])]
        if ch.tokens is not None:
            sl.append(slice(ch.tokens[0], ch.tokens[1]))
        out[tuple(sl)] = arr
    return out


# ---------------------------------------------------------------------------
# stream_out / stream_in — repartition between pipeline topologies
# ---------------------------------------------------------------------------

def _leaf_items(state: Dict, prefix: str = "") -> List[Tuple[str, np.ndarray]]:
    items = []
    for k, v in state.items():
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            items.extend(_leaf_items(v, path + "/"))
        else:
            items.append((path, v))
    return items


def stream_out(state: Dict, src_stage: int, src_topo: PipelineTopo,
               dst_topo: PipelineTopo, dst_stores: Dict[int, HostMemoryStore],
               transport: Transport, *, mb: int | str = 0,
               token_range: Optional[Tuple[int, int]] = None) -> int:
    """Send this stage's slice of the decode state to the destination
    pipeline's stores, splitting/merging by layers and batch.  Returns bytes."""
    plan = plan_repartition(src_topo, dst_topo)
    my_lr = src_topo.layer_range(src_stage)
    total = 0
    for leaf, arr in _leaf_items(state):
        arr = np.asarray(arr)
        has_tok = arr.ndim >= 3 and leaf.startswith(("kv", "cross"))
        has_lb = arr.ndim >= 2 and arr.shape[0] >= 1 and leaf not in ("swa_pos",)
        if not has_lb:  # metadata leaf: replicate to every dst stage
            for ds, st in dst_stores.items():
                total += flush(arr, st, f"mb{mb}/{leaf}", transport)
            continue
        for ss, ds, lr, br in plan:
            if ss != src_stage:
                continue
            # local layer index offset within this stage's slice
            lr_local = (lr[0] - my_lr[0], lr[1] - my_lr[0])
            if lr_local[0] < 0 or lr_local[1] > arr.shape[0]:
                continue
            sl = [slice(*lr_local), slice(*br)]
            tok = None
            if has_tok:
                tok = token_range or (0, arr.shape[TOKEN_AXIS])
                sl.append(slice(*tok))
            chunk = CacheChunk(leaf, lr, br, tok)
            total += flush(arr[tuple(sl)], dst_stores[ds], chunk.key(mb), transport)
    return total


def stream_out_blocks(block_arrays: Dict[int, Dict[str, np.ndarray]],
                      src_stage: int, src_topo: PipelineTopo,
                      dst_topo: PipelineTopo, dst_stores: Dict[int, "HostMemoryStore"],
                      transport: Transport, *, seq: int | str) -> int:
    """Block-granularity stream_out: move only LIVE paged-KV blocks.

    `block_arrays`: {logical_block_idx: {"k": [Lstage,w,H,D], "v": ...}} —
    the per-block pages of this stage's layer slice (w <= block_size tokens
    live in the block).  Each block is split by the destination topology's
    layer ranges and flushed under ``seq{seq}/blk{j}/l{lo}-{hi}/{leaf}``.
    Dead/unallocated blocks never touch the wire — the contract the paper's
    §4.1.2 scatter/gather layer makes cheap and static caches make impossible.
    """
    my_lo, my_hi = src_topo.layer_range(src_stage)
    total = 0
    for ds in range(dst_topo.depth):
        dlo, dhi = dst_topo.layer_range(ds)
        ov = _overlap((my_lo, my_hi), (dlo, dhi))
        if ov is None:
            continue
        lo, hi = ov
        for j, arrays in block_arrays.items():
            for leaf, arr in arrays.items():
                key = f"seq{seq}/blk{j}/l{lo}-{hi}/{leaf}"
                total += flush(arr[lo - my_lo:hi - my_lo], dst_stores[ds], key,
                               transport, n_messages=1)
    return total


def stream_in_blocks(store, dst_stage: int, dst_topo: PipelineTopo,
                     src_topo: PipelineTopo, transport: Transport, *,
                     seq: int | str, cleanup: bool = True
                     ) -> Dict[int, Dict[str, np.ndarray]]:
    """Reassemble this stage's slice of every streamed block of `seq`.

    Inverse of `stream_out_blocks`: fetches the layer-overlap chunks landed
    by each source stage and concatenates them into the destination stage's
    local layer frame.  Returns {logical_block_idx: {"k": ..., "v": ...}}."""
    my_lo, my_hi = dst_topo.layer_range(dst_stage)
    pieces: Dict[int, Dict[str, Dict[int, np.ndarray]]] = {}
    for ss in range(src_topo.depth):
        slo, shi = src_topo.layer_range(ss)
        ov = _overlap((my_lo, my_hi), (slo, shi))
        if ov is None:
            continue
        lo, hi = ov
        prefix = f"seq{seq}/blk"
        for key in store.keys():
            if not key.startswith(prefix) or f"/l{lo}-{hi}/" not in key:
                continue
            j = int(key[len(prefix):].split("/")[0])
            leaf = key.rsplit("/", 1)[1]
            arr = fetch(store, key, transport)
            pieces.setdefault(j, {}).setdefault(leaf, {})[lo] = arr
            if cleanup:
                store.delete(key)
    out: Dict[int, Dict[str, np.ndarray]] = {}
    for j, leaves in pieces.items():
        out[j] = {leaf: np.concatenate([chunks[lo] for lo in sorted(chunks)], 0)
                  for leaf, chunks in leaves.items()}
    return out


def stream_in(store, dst_stage: int, dst_topo: PipelineTopo,
              src_topo: PipelineTopo, state_shapes: Dict,
              transport: Transport, *, mb: int | str = 0,
              token_range: Optional[Tuple[int, int]] = None) -> Dict:
    """Rebuild this stage's local decode state from streamed chunks.

    `state_shapes`: nested dict of (shape, dtype) for the LOCAL (per-stage)
    state.  Shapes' layer axis is this stage's layer count."""
    plan = plan_repartition(src_topo, dst_topo)
    my_lr = dst_topo.layer_range(dst_stage)

    def build(shapes, prefix=""):
        out = {}
        for k, v in shapes.items():
            path = f"{prefix}{k}"
            if isinstance(v, dict):
                out[k] = build(v, path + "/")
                continue
            shape, dtype = v
            if path == "swa_pos" or len(shape) < 2:
                out[k] = fetch(store, f"mb{mb}/{path}", transport)
                continue
            has_tok = len(shape) >= 3 and path.startswith(("kv", "cross"))
            chunks = []
            for ss, ds, lr, br in plan:
                if ds != dst_stage:
                    continue
                tok = (token_range or (0, shape[TOKEN_AXIS])) if has_tok else None
                # global chunk -> local placement (shift layers to local frame)
                chunks.append(CacheChunk(path, lr, br, tok))
            dense = np.zeros(shape, np.dtype(dtype))
            for ch in chunks:
                arr = fetch(store, ch.key(mb), transport)
                sl = [slice(ch.layers[0] - my_lr[0], ch.layers[1] - my_lr[0]),
                      slice(*ch.batch)]
                if ch.tokens is not None:
                    sl.append(slice(*ch.tokens))
                dense[tuple(sl)] = arr
            out[k] = dense
        return out

    return build(state_shapes)
