from repro.core.dejavulib.buffers import HostMemoryStore, SSDStore, TransferRecord
from repro.core.dejavulib.transport import (HardwareModel, Transport,
                                            LocalTransport, HostLinkTransport,
                                            NetworkTransport, ICITransport,
                                            SSDTransport)
from repro.core.dejavulib.primitives import (CacheChunk, flush, fetch, scatter,
                                             gather, stream_out, stream_in,
                                             stream_out_blocks,
                                             stream_in_blocks,
                                             plan_repartition, PipelineTopo)
from repro.core.dejavulib.streamer import StreamEngine

__all__ = [
    "HostMemoryStore", "SSDStore", "TransferRecord", "HardwareModel",
    "Transport", "LocalTransport", "HostLinkTransport", "NetworkTransport",
    "ICITransport", "SSDTransport", "CacheChunk", "flush", "fetch", "scatter",
    "gather",
    "stream_out", "stream_in", "stream_out_blocks", "stream_in_blocks",
    "plan_repartition", "PipelineTopo", "StreamEngine",
]
