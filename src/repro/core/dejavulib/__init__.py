from repro.core.dejavulib.buffers import HostMemoryStore, SSDStore, TransferRecord
from repro.core.dejavulib.faults import (FaultInjected, FaultInjector,
                                         FaultPlan, FaultSpec, FiredFault,
                                         StreamTaskError, assert_no_leaks)
from repro.core.dejavulib.primitives import (CacheChunk, PipelineTopo, fetch,
                                             flush, gather, plan_repartition,
                                             scatter, stream_in,
                                             stream_in_blocks, stream_out,
                                             stream_out_blocks)
from repro.core.dejavulib.streamer import StreamEngine
from repro.core.dejavulib.transport import (HardwareModel, HostLinkTransport,
                                            ICITransport, LocalTransport,
                                            NetworkTransport, SSDTransport,
                                            Transport)

__all__ = [
    "HostMemoryStore", "SSDStore", "TransferRecord", "HardwareModel",
    "Transport", "LocalTransport", "HostLinkTransport", "NetworkTransport",
    "ICITransport", "SSDTransport", "CacheChunk", "flush", "fetch", "scatter",
    "gather",
    "stream_out", "stream_in", "stream_out_blocks", "stream_in_blocks",
    "plan_repartition", "PipelineTopo", "StreamEngine",
    "FaultInjected", "FaultInjector", "FaultPlan", "FaultSpec", "FiredFault",
    "StreamTaskError", "assert_no_leaks",
]
