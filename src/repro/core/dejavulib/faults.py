"""Deterministic fault injection for DejaVuLib (paper §5: fault tolerance).

DéjàVu's recovery story (KV-cache replication + streaming restore) is only
as good as the failure scenarios it is tested under.  The serving engine's
historical ``fail_at={gstep: wid}`` hook can kill a worker *between* steps,
but every finer-grained streaming op — a background stream task, a transport
transfer, a tier demotion, an SSD write — was implicitly assumed to never
fail mid-flight.  This module makes those boundaries a first-class, tested
surface: named **injection points** are woven through the DejaVuLib hot
paths, each point keeps a deterministic per-run occurrence count, and a
:class:`FaultPlan` targets "the Nth occurrence of point P" with a chosen
fault kind.

Injection points (see docs/faults.md for the catalog):

==========================  =====================================================
point                       fired from
==========================  =====================================================
``engine.step``             ServingEngine, once per scheduled sequence-step
``cluster.fail``            DejaVuCluster.inject_failure (observability only)
``stream.submit``           StreamEngine.submit (caller thread)
``stream.task``             StreamEngine worker thread, before running a task
``stream.wait``             StreamEngine.wait (caller thread)
``stream.drain``            StreamEngine.drain, before the barrier
``transport.transfer.<k>``  Transport.transfer, ``<k>`` in local/hostlink/
                            ici/net/ssd (one counter per link kind)
``tier.demote``             KVTierManager demotion (HBM→host, host→SSD spill)
``tier.promote``            KVTierManager._read (promotion toward HBM)
``ssd.put``                 SSDStore.put, between the fsync'd temp write and
                            the atomic rename
==========================  =====================================================

Fault kinds and how each site realizes them:

- ``worker_death``  — calls the installed ``worker_killer(wid)`` (the engine
  binds this to ``DejaVuCluster.inject_failure``); the op itself proceeds.
- ``error``         — raises :class:`FaultInjected` at the point (a hard,
  non-retryable crash of that op).
- ``task_error``    — raises at ``stream.task``; the stream worker treats it
  as transient and retries the task once (the counter has advanced, so the
  retry runs clean).
- ``ssd_write``     — raises inside ``SSDStore.put`` before the rename; the
  temp file is removed, the published block is untouched (old-or-none).
  Stream tasks treat it as transient and retry, like ``task_error``.
- ``drop``          — returned to the site: Transport.transfer retransmits
  and charges the modeled time of both attempts.
- ``corrupt``       — returned to the site: Transport.transfer flips a byte
  in the received copy, detects the mismatch (stand-in for a checksum), and
  retransmits.
- ``delay``         — returned to the site: a straggler; ``delay_s`` modeled
  seconds are charged to the site's timeline (no data effect).

Determinism: points fired from the StreamEngine worker thread (``stream.task``,
background transfers, ``ssd.put``) are serialized by the FIFO queue, and the
cluster drains the streamer at fixed barriers (after each replication round,
before tier reads), so per-point occurrence counts are reproducible across
runs of the same workload.  The crash-consistency sweep in
``tests/test_crash_consistency.py`` leans on this: it records the
injection-point trace of a reference run, then re-runs once per point with a
fault at the middle occurrence, asserting token-identical recovered output
and zero leaked pool/tier blocks.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core import telemetry
from repro.core import tracing

FAULT_KINDS = ("error", "task_error", "worker_death", "drop", "corrupt",
               "ssd_write", "delay")

#: kinds the StreamEngine worker treats as transient (one deterministic retry)
RETRYABLE_KINDS = frozenset({"task_error", "ssd_write"})

#: kinds realized locally by the firing site (fire() returns the spec)
_SITE_KINDS = frozenset({"drop", "corrupt", "delay"})

#: kinds that raise FaultInjected out of fire()
_RAISE_KINDS = frozenset({"error", "task_error", "ssd_write"})


class FaultInjected(Exception):
    """Raised by :meth:`FaultInjector.fire` for raising fault kinds.

    Deliberately NOT a RuntimeError: the serving engine's recovery paths
    catch RuntimeError as "a worker died"; an injected op crash must not be
    silently absorbed by that handler unless a site chooses to retry it.
    """

    def __init__(self, spec: "FaultSpec", point: str, n: int):
        super().__init__(
            f"injected fault {spec.kind!r} at {point!r} occurrence {n}")
        self.spec = spec
        self.point = point
        self.n = n


class StreamTaskError(Exception):
    """One or more fire-and-forget stream tasks failed in the background.

    Raised by ``StreamEngine.drain()`` / ``close()`` with the first failure
    as ``__cause__``.  Not a RuntimeError for the same reason as
    :class:`FaultInjected`.
    """


@dataclass(frozen=True)
class FaultSpec:
    """Fault one (or a window of) occurrence(s) of a named injection point.

    ``nth`` is 1-based; the spec matches occurrences ``nth .. nth+times-1``.
    """
    point: str
    nth: int
    kind: str = "error"
    wid: Optional[int] = None      # worker_death target
    delay_s: float = 0.0           # delay kind: modeled straggler seconds
    times: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.nth < 1 or self.times < 1:
            raise ValueError("nth and times are 1-based counts")
        if self.kind == "worker_death" and self.wid is None:
            raise ValueError("worker_death spec needs a target wid")

    def matches(self, point: str, n: int) -> bool:
        return point == self.point and self.nth <= n < self.nth + self.times


class FaultPlan:
    """An ordered collection of :class:`FaultSpec`s, indexed by point."""

    def __init__(self, specs: Iterable[FaultSpec] = ()):
        self._by_point: Dict[str, List[FaultSpec]] = {}
        self.specs: List[FaultSpec] = []
        for s in specs:
            self.add(s)

    def add(self, spec: FaultSpec) -> "FaultPlan":
        self.specs.append(spec)
        self._by_point.setdefault(spec.point, []).append(spec)
        return self

    def match(self, point: str, n: int) -> Optional[FaultSpec]:
        for s in self._by_point.get(point, ()):
            if s.matches(point, n):
                return s
        return None

    @classmethod
    def from_fail_at(cls, fail_at: Dict[int, int],
                     point: str = "engine.step") -> "FaultPlan":
        """Shim: the legacy ``fail_at={gstep: wid}`` kwarg as a plan.

        ``engine.step`` fires exactly once per global step, so occurrence
        number == gstep and the old semantics carry over unchanged.
        """
        return cls(FaultSpec(point, nth=g, kind="worker_death", wid=w)
                   for g, w in sorted(fail_at.items()))

    def __len__(self) -> int:
        return len(self.specs)

    def __repr__(self) -> str:
        return f"FaultPlan({self.specs!r})"


@dataclass
class FiredFault:
    """One realized fault (what EngineReport.fault_trace carries)."""
    point: str
    n: int
    kind: str
    tag: str = ""
    wid: Optional[int] = None


class FaultInjector:
    """Counts injection-point occurrences and realizes a :class:`FaultPlan`.

    One injector == one run.  ``counts`` maps point → occurrences seen;
    with ``record=True`` every firing is appended to ``trace`` as
    ``(point, n, tag)`` — the crash-consistency sweep records a reference
    trace this way, then replays it one fault at a time.  ``fired`` lists
    the faults actually realized.
    """

    def __init__(self, plan: Optional[FaultPlan] = None, record: bool = False):
        self.plan = plan if plan is not None else FaultPlan()
        self.record = record
        self.counts: Dict[str, int] = {}
        self.trace: List[Tuple[str, int, str]] = []
        self.fired: List[FiredFault] = []
        self.worker_killer: Optional[Callable[[Optional[int]], None]] = None
        self._lock = threading.Lock()

    def fire(self, point: str, tag: str = "") -> Optional[FaultSpec]:
        """Count one occurrence of `point`; realize a planned fault if any.

        Returns None (no fault, or a worker_death already delivered via
        ``worker_killer``), returns the spec for site-realized kinds
        (drop/corrupt/delay), or raises :class:`FaultInjected`.
        """
        with self._lock:
            n = self.counts.get(point, 0) + 1
            self.counts[point] = n
            if self.record:
                self.trace.append((point, n, tag))
            spec = self.plan.match(point, n)
            if spec is not None:
                self.fired.append(
                    FiredFault(point, n, spec.kind, tag, spec.wid))
        if spec is None:
            return None
        telemetry.count("faults.fired", 1, kind=spec.kind, point=point)
        if tracing.active():
            # every realized fault becomes a trace instant (auto-routed to
            # the firing thread's track), so failures show up inline in a
            # flight-recorder dump next to the passes they disrupted
            tracing.event(f"fault.{spec.kind}", point=point, n=n, tag=tag,
                          **({} if spec.wid is None else {"wid": spec.wid}))
        # Actions run OUTSIDE the lock: worker_killer may re-enter fire()
        # (inject_failure fires "cluster.fail").
        if spec.kind == "worker_death":
            if self.worker_killer is None:
                raise FaultInjected(spec, point, n)
            self.worker_killer(spec.wid)
            return None
        if spec.kind in _RAISE_KINDS:
            raise FaultInjected(spec, point, n)
        return spec


# ---------------------------------------------------------------------------
# Module-global installation.  Sites call `faults.fire(point, tag)`; with no
# injector installed that is a near-free early-out, so instrumented hot paths
# cost nothing in normal serving.

_ACTIVE: Optional[FaultInjector] = None


def install(inj: FaultInjector) -> FaultInjector:
    global _ACTIVE
    _ACTIVE = inj
    return inj


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def current() -> Optional[FaultInjector]:
    return _ACTIVE


@contextmanager
def active(inj: FaultInjector):
    """Install `inj` for the duration of a with-block (restores the prior)."""
    prev = _ACTIVE
    install(inj)
    try:
        yield inj
    finally:
        if prev is None:
            uninstall()
        else:
            install(prev)


def fire(point: str, tag: str = "") -> Optional[FaultSpec]:
    inj = _ACTIVE
    if inj is None:
        return None
    return inj.fire(point, tag)


# ---------------------------------------------------------------------------
# Crash-consistency sweep driver helpers (engine-agnostic; the test module
# owns workload construction).

def survivable_kinds(point: str) -> List[str]:
    """Fault kinds a correct implementation must fully recover from at
    `point` — token-identical output, no leaked blocks (docs/faults.md)."""
    if point in ("engine.step", "stream.drain"):
        return ["worker_death"]
    if point == "stream.task":
        return ["task_error", "delay"]
    if point.startswith("transport.transfer."):
        return (["corrupt", "drop", "delay"]
                if point.endswith(".net") else ["drop", "delay"])
    if point == "ssd.put":
        return ["ssd_write"]
    if point in ("tier.demote", "tier.promote", "stream.submit",
                 "stream.wait"):
        return ["delay"]
    if point == "cluster.fail":
        return []          # this IS the failure mechanism, not a victim
    return ["delay"]


def spec_for_point(point: str, count: int, kind: Optional[str] = None, *,
                   wid: Optional[int] = None, nth: Optional[int] = None,
                   delay_s: float = 1e-3) -> FaultSpec:
    """Build the sweep's spec for `point` seen `count` times on the
    reference trace: middle occurrence, first survivable kind by default."""
    if kind is None:
        kinds = survivable_kinds(point)
        if not kinds:
            raise ValueError(f"point {point!r} has no survivable fault kinds")
        kind = kinds[0]
    if nth is None:
        nth = (count + 1) // 2 or 1
    return FaultSpec(point, nth=nth, kind=kind, wid=wid, delay_s=delay_s)


def coverage_summary(reference: FaultInjector,
                     exercised: Dict[str, dict]) -> dict:
    """JSON-able points-seen vs points-exercised summary (CI artifact)."""
    seen = dict(sorted(reference.counts.items()))
    return {
        "points_seen": seen,
        "points_exercised": exercised,
        "unexercised": sorted(p for p in seen
                              if p not in exercised and survivable_kinds(p)),
    }


def assert_no_leaks(cluster) -> None:
    """Post-run invariant: every retired sequence released everything.

    Checks, per live worker: (a) the block pool is fully free and holds no
    page tables; (b) no ``pagedswap/`` residue in the host store or replica
    stores; (c) the KV tier holds no ``swap``-kind entries (prefix-cache
    entries are legitimate — they are a cache, not ownership).
    """
    workers = list(dict.fromkeys(
        list(getattr(cluster, "prompt_group", [])) +
        list(getattr(cluster, "token_group", []))))
    for w in workers:
        pool = getattr(w, "pool", None)
        if pool is not None:
            used = pool.num_used()
            if used:
                raise AssertionError(
                    f"worker {w.wid}: {used} pool block(s) leaked")
            if getattr(pool, "tables", None):
                raise AssertionError(
                    f"worker {w.wid}: page tables leaked: "
                    f"{sorted(pool.tables)}")
        cache = getattr(w, "cache", None)
        if cache is not None:
            stale = [k for k in cache.host.keys()
                     if k.startswith("pagedswap/")]
            if stale:
                raise AssertionError(
                    f"worker {w.wid}: host swap residue: {stale[:4]}...")
            stale = [k for k in cache.replica.keys() if "/seq" in k]
            if stale:
                raise AssertionError(
                    f"worker {w.wid}: replica residue: {stale[:4]}...")
        tier = getattr(w, "tier", None)
        if tier is not None:
            swaps = [e.key for e in tier._entries.values()
                     if e.kind == "swap"]
            if swaps:
                raise AssertionError(
                    f"worker {w.wid}: tier swap entries leaked: {swaps[:4]}")


__all__ = [
    "FAULT_KINDS", "RETRYABLE_KINDS", "FaultInjected", "StreamTaskError",
    "FaultSpec", "FaultPlan", "FiredFault", "FaultInjector",
    "install", "uninstall", "current", "active", "fire",
    "survivable_kinds", "spec_for_point", "coverage_summary",
    "assert_no_leaks",
]
