"""Transport layer: real data movement + calibrated hardware timing model.

The container has one CPU device, so "remote" copies are real numpy copies
between stores while *modeled* time comes from a bandwidth/latency model of
the target deployment (TPU v5e pod).  Every transfer is logged with both
modeled and wall time; benchmarks read the modeled timeline, tests assert on
the real data.

GPU-paper → TPU mapping: NCCL → ICI (50 GB/s/link), PCIe → host link
(16 GB/s), cross-VM 40 Gbps Ethernet → DCN (25 GB/s/pod aggregate, 5 GB/s
per-stream default).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core import telemetry
from repro.core import tracing
from repro.core.dejavulib import faults
from repro.core.dejavulib.buffers import TransferRecord


@dataclass(frozen=True)
class HardwareModel:
    """Target-deployment constants (v5e defaults; planner-configurable)."""
    peak_flops: float = 197e12            # bf16 FLOP/s per chip
    hbm_bw: float = 819e9                 # bytes/s per chip
    ici_bw: float = 50e9                  # bytes/s per link
    host_link_bw: float = 16e9            # device<->host (PCIe-equivalent)
    dcn_stream_bw: float = 5e9            # per-stream cross-pod
    host_mem_bw: float = 100e9            # host DRAM memcpy
    ssd_bw: float = 3e9                   # NVMe sequential write
    transfer_latency: float = 10e-6       # per-transfer fixed overhead (DMA setup)
    net_latency: float = 50e-6            # per-message network overhead
    chips_per_host: int = 4


DEFAULT_HW = HardwareModel()


class Transport:
    """Base transport: copies bytes, charges modeled time, logs records."""

    kind = "base"

    def __init__(self, bandwidth: float, latency: float, name: str = ""):
        self.bandwidth = bandwidth
        self.latency = latency
        self.name = name or self.kind
        self.log: List[TransferRecord] = []
        self._lock = threading.Lock()

    def model_time(self, nbytes: int, n_messages: int = 1) -> float:
        return self.latency * n_messages + nbytes / self.bandwidth

    def transfer(self, array: np.ndarray, *, tag: str = "",
                 n_messages: int = 1) -> np.ndarray:
        """Copy `array` across this transport; returns the received copy.

        Fires the ``transport.transfer.<kind>`` injection point.  A ``drop``
        fault loses the first copy in flight; a ``corrupt`` fault flips a
        byte of the received copy, which the integrity check (stand-in for a
        checksum) detects.  Either way the transfer retransmits — the caller
        always receives exact bytes — and the modeled timeline is charged
        for every attempt, so the straggler cost of a lossy link stays
        visible to the overlap/benchmark accounting.
        """
        t0 = time.perf_counter()
        out = np.array(array, copy=True)
        attempts, note = 1, ""
        # Fault realization — including the O(nbytes) `tobytes` integrity
        # check standing in for a checksum — lives behind the injector
        # gate: with no injector installed the hot streaming path is one
        # copy + bookkeeping, never a byte-wise comparison.
        spec = None
        if faults.current() is not None:
            spec = faults.fire(f"transport.transfer.{self.kind}", tag=tag)
            if spec is not None and spec.kind in ("drop", "corrupt"):
                out, attempts, note = self._realize_loss(spec, array, out)
        wall = time.perf_counter() - t0
        model = self.model_time(out.nbytes, n_messages) * attempts
        if spec is not None and spec.kind == "delay":
            model += spec.delay_s                # injected straggler
        rec = TransferRecord(self.kind, out.nbytes, model, wall, tag + note)
        with self._lock:
            self.log.append(rec)
        telemetry.count("transport.transfers", 1, kind=self.kind)
        telemetry.count("transport.bytes", out.nbytes, kind=self.kind)
        telemetry.count_time("transport.model_ns", model, kind=self.kind)
        if attempts > 1:
            telemetry.count("transport.retransmits", 1, kind=self.kind)
        if tracing.active():
            # runs on BOTH the serving and the streamer thread; the tracer
            # routes each to its thread's track with the modeled duration
            tracing.event("xfer", kind=self.kind, bytes=out.nbytes,
                          attempts=attempts, tag=tag,
                          dur_ns=int(round(model * 1e9)))
        return out

    @staticmethod
    def _realize_loss(spec, array: np.ndarray, out: np.ndarray):
        """Apply a drop/corrupt fault and detect it via the integrity check."""
        if spec.kind == "drop":
            out = None                           # receiver saw nothing
        else:
            flat = out.reshape(-1).view(np.uint8)
            if flat.size:
                flat[0] ^= 0xFF                  # bit-flip in flight
        src = np.asarray(array)
        if out is None or out.tobytes() != src.tobytes():
            out = np.array(array, copy=True)     # retransmit
            return out, 2, f"+retry({spec.kind})"
        return out, 1, ""

    def modeled_total(self) -> float:
        with self._lock:
            return sum(r.model_seconds for r in self.log)

    def bytes_total(self) -> int:
        with self._lock:
            return sum(r.nbytes for r in self.log)

    def reset_log(self) -> None:
        with self._lock:
            self.log.clear()


class LocalTransport(Transport):
    """Same-host DRAM copy."""
    kind = "local"

    def __init__(self, hw: HardwareModel = DEFAULT_HW):
        super().__init__(hw.host_mem_bw, hw.transfer_latency)


class HostLinkTransport(Transport):
    """Device HBM <-> host RAM (the PCIe role in the paper; swap path)."""
    kind = "hostlink"

    def __init__(self, hw: HardwareModel = DEFAULT_HW):
        super().__init__(hw.host_link_bw, hw.transfer_latency)


class ICITransport(Transport):
    """Chip-to-chip intra-pod (NCCL role for P→T transfers inside a pod)."""
    kind = "ici"

    def __init__(self, hw: HardwareModel = DEFAULT_HW):
        super().__init__(hw.ici_bw, hw.transfer_latency)


class NetworkTransport(Transport):
    """Cross-host / cross-pod stream (the paper's 40 Gbps inter-VM link)."""
    kind = "net"

    def __init__(self, hw: HardwareModel = DEFAULT_HW,
                 bandwidth: Optional[float] = None):
        super().__init__(bandwidth or hw.dcn_stream_bw, hw.net_latency)


class SSDTransport(Transport):
    """Host RAM <-> local NVMe (tier-2 spill/promotion in the KV hierarchy)."""
    kind = "ssd"

    def __init__(self, hw: HardwareModel = DEFAULT_HW):
        super().__init__(hw.ssd_bw, hw.transfer_latency)
