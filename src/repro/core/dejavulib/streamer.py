"""StreamEngine — background streaming thread with compute overlap.

The paper uses a dedicated CPU thread + CUDA streams so KV-cache streaming
overlaps with GPU compute (§4.1 opts 2–3).  Here a single worker thread
drains a FIFO of transfer closures while the main thread computes; the
modeled timeline tracks how much of the streaming time was hidden.

Overlap accounting (simulated-hardware time): each submitted task carries a
`model_seconds` estimate; `overlap_report()` compares total streamed time
against the compute intervals registered via `compute_span()` — the exposed
(non-hidden) streaming time is what DéjàVu's optimizations minimize.

Error handling: `wait()` on a task re-raises its error directly.  Errors of
fire-and-forget tasks nobody waits on are collected and re-raised (first
failure as ``__cause__`` of a :class:`~repro.core.dejavulib.faults.
StreamTaskError`) at the next `drain()` or `close()` barrier, so a failed
background replication or spill can never be silently dropped.

Fault injection: `submit` / the worker loop / `wait` / `drain` fire the
``stream.submit`` / ``stream.task`` / ``stream.wait`` / ``stream.drain``
points (see `repro.core.dejavulib.faults`).  An injected transient fault
(`task_error`, or an `ssd_write` raised from inside the closure) is retried
once by the worker thread — the paper's streaming layer retransmits on
recoverable I/O errors rather than declaring the node dead.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core import telemetry
from repro.core import tracing
from repro.core.dejavulib import faults


@dataclass
class _Task:
    fn: Callable[[], object]
    model_seconds: float
    tag: str
    done: threading.Event = field(default_factory=threading.Event)
    result: object = None
    error: Optional[BaseException] = None


class StreamEngine:
    def __init__(self, name: str = "streamer"):
        self.name = name
        self._q: "queue.Queue[Optional[_Task]]" = queue.Queue()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"dejavu-{name}")
        self._thread.start()
        self._stream_model_time = 0.0
        self._compute_model_time = 0.0
        self._lock = threading.Lock()
        self._errors: List[_Task] = []   # failed tasks nobody waited on yet
        self._closed = False
        self._submit_lock = threading.Lock()

    def _run(self):
        while True:
            task = self._q.get()
            if task is None:
                return
            extra_model = 0.0
            try:
                spec = faults.fire("stream.task", tag=task.tag)
                if spec is not None and spec.kind == "delay":
                    extra_model = spec.delay_s       # injected straggler
                task.result = task.fn()
            except faults.FaultInjected as e:
                if e.spec.kind in faults.RETRYABLE_KINDS:
                    telemetry.count("stream.retries")
                    try:                 # transient I/O fault: one retry
                        task.result = task.fn()
                    except BaseException as e2:
                        task.error = e2
                else:
                    task.error = e
            except BaseException as e:   # surfaced on wait()/drain()/close()
                task.error = e
            if task.error is not None:
                telemetry.count("stream.task_errors")
                with self._lock:
                    self._errors.append(task)
            with self._lock:
                self._stream_model_time += task.model_seconds + extra_model
            # integer-ns counters only from this thread: no spans, no clock
            telemetry.count("stream.tasks_done")
            telemetry.count_time("stream.model_ns",
                                 task.model_seconds + extra_model)
            if tracing.active():
                # non-owner thread: lands on the streamer track at its own
                # FIFO cursor (never reads the modeled clock)
                tracing.event("stream.task", tag=task.tag,
                              dur_ns=int(round(
                                  (task.model_seconds + extra_model) * 1e9)),
                              failed=task.error is not None)
            task.done.set()

    def submit(self, fn: Callable[[], object], *, model_seconds: float = 0.0,
               tag: str = "") -> _Task:
        spec = faults.fire("stream.submit", tag=tag)
        if spec is not None and spec.kind == "delay":
            model_seconds += spec.delay_s
        t = _Task(fn, model_seconds, tag)
        telemetry.count("stream.tasks_submitted")
        with self._submit_lock:
            if self._closed:
                raise RuntimeError(
                    f"stream engine {self.name!r} is closed; "
                    f"cannot submit {tag!r}")
            self._q.put(t)
        return t

    def wait(self, task: _Task, timeout: Optional[float] = None):
        faults.fire("stream.wait", tag=task.tag)
        if not task.done.wait(timeout):
            raise TimeoutError(f"stream task {task.tag!r} timed out")
        if task.error is not None:
            with self._lock:
                if task in self._errors:     # waited-on: caller handles it
                    self._errors.remove(task)
            raise task.error
        return task.result

    def drain(self, timeout: float = 60.0) -> None:
        """Block until the queue is empty (barrier); surface background
        errors of fire-and-forget tasks that failed since the last barrier."""
        faults.fire("stream.drain", tag=self.name)
        sentinel = self.submit(lambda: None, tag="drain")
        self.wait(sentinel, timeout)
        self._raise_background_errors()

    def _raise_background_errors(self) -> None:
        with self._lock:
            failed, self._errors = self._errors, []
        if failed:
            first = failed[0]
            raise StreamTaskError(
                f"{len(failed)} background stream task(s) failed on "
                f"{self.name!r}; first: {first.tag!r} "
                f"({type(first.error).__name__}: {first.error})"
            ) from first.error

    def compute_span(self, model_seconds: float) -> None:
        """Register compute time available to hide streaming behind."""
        with self._lock:
            self._compute_model_time += model_seconds

    def overlap_report(self) -> dict:
        with self._lock:
            hidden = min(self._stream_model_time, self._compute_model_time)
            exposed = self._stream_model_time - hidden
            return {"stream_s": self._stream_model_time,
                    "compute_s": self._compute_model_time,
                    "hidden_s": hidden, "exposed_s": exposed}

    def reset(self) -> None:
        with self._lock:
            self._stream_model_time = 0.0
            self._compute_model_time = 0.0

    def close(self) -> None:
        """Stop the worker thread.  Idempotent.  Raises if the thread fails
        to exit or if background tasks failed and were never surfaced."""
        with self._submit_lock:
            first_close = not self._closed
            self._closed = True
            if first_close:
                self._q.put(None)        # sentinel: drain queue, then exit
        self._thread.join(timeout=5)
        if self._thread.is_alive():
            raise RuntimeError(
                f"stream engine {self.name!r}: worker thread did not exit")
        self._raise_background_errors()


StreamTaskError = faults.StreamTaskError   # re-export at the raising site
