"""StreamEngine — background streaming thread with compute overlap.

The paper uses a dedicated CPU thread + CUDA streams so KV-cache streaming
overlaps with GPU compute (§4.1 opts 2–3).  Here a single worker thread
drains a FIFO of transfer closures while the main thread computes; the
modeled timeline tracks how much of the streaming time was hidden.

Overlap accounting (simulated-hardware time): each submitted task carries a
`model_seconds` estimate; `overlap_report()` compares total streamed time
against the compute intervals registered via `compute_span()` — the exposed
(non-hidden) streaming time is what DéjàVu's optimizations minimize.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class _Task:
    fn: Callable[[], object]
    model_seconds: float
    tag: str
    done: threading.Event = field(default_factory=threading.Event)
    result: object = None
    error: Optional[BaseException] = None


class StreamEngine:
    def __init__(self, name: str = "streamer"):
        self.name = name
        self._q: "queue.Queue[Optional[_Task]]" = queue.Queue()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"dejavu-{name}")
        self._thread.start()
        self._stream_model_time = 0.0
        self._compute_model_time = 0.0
        self._lock = threading.Lock()

    def _run(self):
        while True:
            task = self._q.get()
            if task is None:
                return
            try:
                task.result = task.fn()
            except BaseException as e:  # surfaced on wait()
                task.error = e
            with self._lock:
                self._stream_model_time += task.model_seconds
            task.done.set()

    def submit(self, fn: Callable[[], object], *, model_seconds: float = 0.0,
               tag: str = "") -> _Task:
        t = _Task(fn, model_seconds, tag)
        self._q.put(t)
        return t

    @staticmethod
    def wait(task: _Task, timeout: Optional[float] = None):
        if not task.done.wait(timeout):
            raise TimeoutError(f"stream task {task.tag!r} timed out")
        if task.error is not None:
            raise task.error
        return task.result

    def drain(self, timeout: float = 60.0) -> None:
        """Block until the queue is empty (barrier)."""
        sentinel = self.submit(lambda: None, tag="drain")
        self.wait(sentinel, timeout)

    def compute_span(self, model_seconds: float) -> None:
        """Register compute time available to hide streaming behind."""
        with self._lock:
            self._compute_model_time += model_seconds

    def overlap_report(self) -> dict:
        with self._lock:
            hidden = min(self._stream_model_time, self._compute_model_time)
            exposed = self._stream_model_time - hidden
            return {"stream_s": self._stream_model_time,
                    "compute_s": self._compute_model_time,
                    "hidden_s": hidden, "exposed_s": exposed}

    def reset(self) -> None:
        with self._lock:
            self._stream_model_time = 0.0
            self._compute_model_time = 0.0

    def close(self) -> None:
        self._q.put(None)
        self._thread.join(timeout=5)
