"""Host-side buffer stores for KV-cache streaming.

`HostMemoryStore` models a node's pinned CPU memory — the paper's swap /
replication target and tier 1 of the KV-cache hierarchy managed by
:class:`repro.kvcache.tiers.KVTierManager`.  `SSDStore` persists to disk
(tier 2, the paper's "persistent storage" replication option) with atomic,
fsync'd writes so a crashed writer never leaves a torn replica.

Capacity is enforced on every `put`: the store either raises
(``on_full="raise"``, the default) or evicts least-recently-used entries
(``on_full="evict_lru"``), handing each victim to an optional ``spill_cb``
so a caller can demote it down-tier instead of dropping it.  (The tier
manager plans block placement itself, one level up, and keeps the store in
the ``"raise"`` mode as a hard backstop on its accounting.)
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.dejavulib import faults


@dataclass
class TransferRecord:
    kind: str            # e.g. "flush", "fetch", "net", "pack"
    nbytes: int
    model_seconds: float  # simulated-hardware time (bandwidth/latency model)
    wall_seconds: float   # actual wall time on this container
    tag: str = ""


class HostMemoryStore:
    """Named numpy buffer store with capacity accounting (pinned host RAM).

    ``on_full`` decides what happens when a `put` would exceed
    ``capacity_bytes``: ``"raise"`` (MemoryError, nothing stored) or
    ``"evict_lru"`` (oldest-touched entries are removed until the new array
    fits; each victim is passed to ``spill_cb(key, array)`` if given, so a
    caller can demote it to a lower tier instead of losing it)."""

    def __init__(self, name: str = "host", capacity_bytes: Optional[int] = None,
                 on_full: str = "raise",
                 spill_cb: Optional[Callable[[str, np.ndarray], None]] = None):
        assert on_full in ("raise", "evict_lru")
        self.name = name
        self.capacity_bytes = capacity_bytes
        self.on_full = on_full
        self.spill_cb = spill_cb
        self._data: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self._lock = threading.Lock()

    def put(self, key: str, array: np.ndarray) -> List[Tuple[str, np.ndarray]]:
        """Store `array` under `key`.  Returns the list of (key, array)
        entries evicted to make room (empty unless ``on_full="evict_lru"``)."""
        arr = np.asarray(array)
        evicted: List[Tuple[str, np.ndarray]] = []
        with self._lock:
            new_bytes = self._used_bytes_locked() - self._nbytes(key) + arr.nbytes
            if self.capacity_bytes is not None and new_bytes > self.capacity_bytes:
                if self.on_full == "raise":
                    raise MemoryError(
                        f"store {self.name!r}: {new_bytes} > capacity "
                        f"{self.capacity_bytes}")
                # evict_lru: shed oldest-touched entries until the put fits
                while new_bytes > self.capacity_bytes:
                    victim_key = next((k for k in self._data if k != key), None)
                    if victim_key is None:
                        break
                    victim = self._data.pop(victim_key)
                    evicted.append((victim_key, victim))
                    new_bytes -= victim.nbytes
                if new_bytes > self.capacity_bytes:
                    raise MemoryError(
                        f"store {self.name!r}: single array of {arr.nbytes} "
                        f"bytes exceeds capacity {self.capacity_bytes}")
            self._data[key] = arr
            self._data.move_to_end(key)
        if self.spill_cb is not None:
            for k, a in evicted:
                self.spill_cb(k, a)
        return evicted

    def get(self, key: str) -> np.ndarray:
        with self._lock:
            arr = self._data[key]
            self._data.move_to_end(key)        # LRU touch
            return arr

    def pop(self, key: str) -> np.ndarray:
        with self._lock:
            return self._data.pop(key)

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def keys(self):
        with self._lock:
            return list(self._data)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def used_bytes(self) -> int:
        with self._lock:
            return self._used_bytes_locked()

    def _used_bytes_locked(self) -> int:
        return sum(a.nbytes for a in self._data.values())

    def _nbytes(self, key: str) -> int:
        a = self._data.get(key)
        return 0 if a is None else a.nbytes

    def clear(self) -> None:
        with self._lock:
            self._data.clear()


class SSDStore:
    """Disk-backed store (npy files, atomic rename).  Survives process death —
    used for persistent KV replication, tier-2 spill of the KV-cache
    hierarchy (`repro.kvcache.tiers`), and checkpoint shards.

    Writes are crash-safe: bytes land in a temp file that is flushed and
    fsync'd BEFORE the atomic ``os.replace`` publishes it, so a reader (e.g.
    failure recovery restoring blocks from the lowest tier) can never observe
    a torn block; a writer crash leaves at worst an orphaned ``*.tmp.*`` file
    that `keys()` ignores."""

    def __init__(self, root: str, name: str = "ssd"):
        self.name = name
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key.replace("/", "__") + ".npy")

    def put(self, key: str, array: np.ndarray) -> None:
        path = self._path(key)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with self._lock:
            try:
                with open(tmp, "wb") as f:   # np.save(str) appends .npy — avoid
                    np.save(f, np.asarray(array))
                    f.flush()
                    os.fsync(f.fileno())     # durable before the rename publishes
                # Crash window under test: bytes are durable in the temp file
                # but not yet published.  A fault here must leave a reader
                # seeing the OLD block (or none) — never a torn one.
                faults.fire("ssd.put", tag=key)
                os.replace(tmp, path)        # atomic
            except BaseException:
                try:
                    os.remove(tmp)           # never leak a partial temp file
                except FileNotFoundError:
                    pass
                raise

    def get(self, key: str) -> np.ndarray:
        return np.load(self._path(key))

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def size(self, key: str) -> int:
        """On-disk bytes of one entry (0 if absent)."""
        try:
            return os.path.getsize(self._path(key))
        except FileNotFoundError:
            return 0

    def keys(self):
        return [f[:-4].replace("__", "/") for f in os.listdir(self.root)
                if f.endswith(".npy")]

    def used_bytes(self) -> int:
        return sum(os.path.getsize(os.path.join(self.root, f))
                   for f in os.listdir(self.root) if f.endswith(".npy"))

    def clear(self) -> None:
        for f in list(os.listdir(self.root)):
            if f.endswith(".npy"):
                os.remove(os.path.join(self.root, f))
