"""Host-side buffer stores for KV-cache streaming.

`HostMemoryStore` models a node's pinned CPU memory (the paper's swap /
replication target); `SSDStore` persists to disk (the paper's "persistent
storage" replication option) with atomic writes so a crashed writer never
leaves a torn replica.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


@dataclass
class TransferRecord:
    kind: str            # e.g. "flush", "fetch", "net", "pack"
    nbytes: int
    model_seconds: float  # simulated-hardware time (bandwidth/latency model)
    wall_seconds: float   # actual wall time on this container
    tag: str = ""


class HostMemoryStore:
    """Named numpy buffer store with capacity accounting (pinned host RAM)."""

    def __init__(self, name: str = "host", capacity_bytes: Optional[int] = None):
        self.name = name
        self.capacity_bytes = capacity_bytes
        self._data: Dict[str, np.ndarray] = {}
        self._lock = threading.Lock()

    def put(self, key: str, array: np.ndarray) -> None:
        arr = np.asarray(array)
        with self._lock:
            new_bytes = self.used_bytes() - self._nbytes(key) + arr.nbytes
            if self.capacity_bytes is not None and new_bytes > self.capacity_bytes:
                raise MemoryError(
                    f"store {self.name!r}: {new_bytes} > capacity {self.capacity_bytes}")
            self._data[key] = arr

    def get(self, key: str) -> np.ndarray:
        with self._lock:
            return self._data[key]

    def pop(self, key: str) -> np.ndarray:
        with self._lock:
            return self._data.pop(key)

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def keys(self):
        with self._lock:
            return list(self._data)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def used_bytes(self) -> int:
        return sum(a.nbytes for a in self._data.values())

    def _nbytes(self, key: str) -> int:
        a = self._data.get(key)
        return 0 if a is None else a.nbytes

    def clear(self) -> None:
        with self._lock:
            self._data.clear()


class SSDStore:
    """Disk-backed store (npy files, atomic rename).  Survives process death —
    used for persistent KV replication and checkpoint shards."""

    def __init__(self, root: str, name: str = "ssd"):
        self.name = name
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key.replace("/", "__") + ".npy")

    def put(self, key: str, array: np.ndarray) -> None:
        path = self._path(key)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with self._lock:
            with open(tmp, "wb") as f:   # np.save(str) appends .npy — avoid
                np.save(f, np.asarray(array))
            os.replace(tmp, path)  # atomic

    def get(self, key: str) -> np.ndarray:
        return np.load(self._path(key))

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def keys(self):
        return [f[:-4].replace("__", "/") for f in os.listdir(self.root)
                if f.endswith(".npy")]

    def used_bytes(self) -> int:
        return sum(os.path.getsize(os.path.join(self.root, f))
                   for f in os.listdir(self.root) if f.endswith(".npy"))

    def clear(self) -> None:
        for f in list(os.listdir(self.root)):
            if f.endswith(".npy"):
                os.remove(os.path.join(self.root, f))
