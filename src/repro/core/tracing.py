"""Per-request causal tracing: a bounded, deterministic flight recorder.

The telemetry registry (`repro.core.telemetry`) deliberately collapses
spans to path-keyed aggregates and never keeps individual events, so it
can answer "what is TTFT p99?" but not "why did request 17's TTFT hit
p99?".  This module is the complementary layer: it records *individual*
events and spans with causal parent links and request/sequence IDs, so a
single run can be replayed into a per-request critical-path breakdown
(`tools/trace_report.py`) or exported to Perfetto / OTLP wire formats
(`repro.core.exporters`).

Design constraints, matching PR 7's telemetry rules:

1. **Determinism.**  Two identical runs produce byte-identical dumps.
   Timestamps are *integer nanoseconds on the modeled clock* (the
   telemetry registry's `clock_s`, read only from the serving thread).
   Events fired from other threads (the DejaVuLib streamer) never read
   the clock: they land on their own *track*, where each event's
   timestamp is the track's running cursor (the accumulated modeled
   duration of the events before it) — the streamer FIFO serializes its
   tasks, so per-track order and cursors are reproducible.  The dump
   keeps each track's own order and never merges across tracks.
2. **Near-free when disabled.**  Call sites use the module helpers
   (`event`, `span`, `active`), a single ``is None`` check when no
   tracer is installed — the same pattern as `telemetry` and
   `dejavulib.faults` (micro-benchmarked in
   ``benchmarks/streaming_breakdown.py``).
3. **Bounded memory, no silent truncation.**  Each track is a
   fixed-capacity ring buffer that overwrites its oldest events
   (flight-recorder semantics); the snapshot reports explicit
   ``dropped`` and ``emitted`` counters per track so a truncated dump
   is always visibly truncated.

Cross-thread rules: span open/close happens on the owner (serving)
thread only — `Tracer.span` raises off-thread, mirroring the telemetry
thread-affinity guard.  `event()` is safe from any thread; non-owner
threads are routed to the ``streamer`` track automatically.

The snapshot is a versioned, JSON-stable schema (``repro.trace/v1``):

```json
{"schema": "repro.trace/v1",
 "capacity": 65536,
 "tracks": {"serve": {"events": [{"eid": 3, "name": "pass", "ph": "X",
                                  "ts": 120000, "dur": 80000,
                                  "parent": 2, "rid": 17, "seq": 4,
                                  "args": {"kind": "fused_decode"}}],
                      "dropped": 0, "emitted": 4}}}
```

``ph`` follows the Chrome trace-event phases the Perfetto exporter
emits: ``"X"`` complete span (``ts`` + ``dur``), ``"I"`` instant.
"""
from __future__ import annotations

import json
import threading
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional

from repro.core import telemetry

SCHEMA = "repro.trace/v1"

#: default per-track ring capacity (events); generous enough that the CI
#: workloads never drop, small enough to bound a runaway run's memory
DEFAULT_CAPACITY = 1 << 16

_NS = 1_000_000_000

#: the track serving-thread events land on by default
SERVE_TRACK = "serve"
#: the track non-owner-thread events are routed to automatically
STREAM_TRACK = "streamer"


class _Track:
    """One ring buffer: fixed capacity, oldest-overwritten, counted drops."""

    __slots__ = ("name", "capacity", "events", "head", "next_eid",
                 "dropped", "emitted", "cursor_ns")

    def __init__(self, name: str, capacity: int):
        self.name = name
        self.capacity = capacity
        self.events: List[dict] = []
        self.head = 0              # index of the OLDEST event once full
        self.next_eid = 0
        self.dropped = 0
        self.emitted = 0
        self.cursor_ns = 0         # running end-time for clock-less threads

    def append(self, ev: dict) -> None:
        self.emitted += 1
        if len(self.events) < self.capacity:
            self.events.append(ev)
            return
        self.events[self.head] = ev    # overwrite the oldest (flight recorder)
        self.head = (self.head + 1) % self.capacity
        self.dropped += 1

    def chronological(self) -> List[dict]:
        return self.events[self.head:] + self.events[:self.head]


class Tracer:
    """The flight recorder: per-track rings + causal span stack.

    One tracer == one run (or one aggregation window).  All mutation is
    lock-protected; the owner thread is bound at construction and
    re-bound by :func:`install`, exactly like the telemetry registry.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._tracks: Dict[str, _Track] = {}
        self._tls = threading.local()
        self._owner = threading.get_ident()

    # -- internals -----------------------------------------------------
    def _track(self, name: str) -> _Track:
        tr = self._tracks.get(name)
        if tr is None:
            tr = self._tracks[name] = _Track(name, self.capacity)
        return tr

    def _stack(self) -> List[int]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _now_ns(self) -> int:
        t = telemetry.current()
        return 0 if t is None else int(round(t.clock_s * _NS))

    @staticmethod
    def _mkev(eid: int, name: str, ph: str, ts: int, dur: int,
              parent: Optional[int], rid: Optional[int],
              seq: Optional[int], args: dict) -> dict:
        ev = {"eid": eid, "name": name, "ph": ph, "ts": ts}
        if dur:
            ev["dur"] = dur
        if parent is not None:
            ev["parent"] = parent
        if rid is not None:
            ev["rid"] = int(rid)
        if seq is not None:
            ev["seq"] = int(seq)
        if args:
            ev["args"] = {k: args[k] for k in sorted(args)}
        return ev

    # -- recording -----------------------------------------------------
    def event(self, name: str, *, track: Optional[str] = None,
              ts_ns: Optional[int] = None, dur_ns: int = 0,
              rid: Optional[int] = None, seq: Optional[int] = None,
              **args: object) -> None:
        """Record one instant (or pre-timed) event.

        Thread routing: on the owner (serving) thread the timestamp is
        the modeled clock and the event lands on `track` (default
        ``serve``) with the current span as causal parent.  On any other
        thread the clock is never read: the event lands on the
        ``streamer`` track (unless `track` is given) at the track's
        running cursor, which then advances by `dur_ns` — callers on
        such threads carry their own modeled durations.
        """
        on_owner = threading.get_ident() == self._owner
        if track is None:
            track = SERVE_TRACK if on_owner else STREAM_TRACK
        parent = None
        if on_owner:
            st = self._stack()
            if st:
                parent = st[-1]
        with self._lock:
            tr = self._track(track)
            if ts_ns is None:
                if on_owner:
                    ts_ns = self._now_ns()
                else:
                    ts_ns = tr.cursor_ns
                    tr.cursor_ns += int(dur_ns)
            eid = tr.next_eid
            tr.next_eid += 1
            ph = "X" if dur_ns else "I"
            tr.append(self._mkev(eid, name, ph, int(ts_ns), int(dur_ns),
                                 parent, rid, seq, args))

    @contextmanager
    def span(self, name: str, *, rid: Optional[int] = None,
             seq: Optional[int] = None, **args: object) -> Iterator[None]:
        """A complete ("X") event timed on the modeled clock, recorded at
        close.  Owner thread only (the clock lives there); the eid is
        reserved at open so children recorded inside link to it."""
        if threading.get_ident() != self._owner:
            raise RuntimeError(
                "Tracer.span: spans open/close on the owner (serving) "
                "thread only; other threads use event(ts/dur) instead")
        st = self._stack()
        parent = st[-1] if st else None
        with self._lock:
            tr = self._track(SERVE_TRACK)
            eid = tr.next_eid
            tr.next_eid += 1
        st.append(eid)
        t0 = self._now_ns()
        try:
            yield
        finally:
            st.pop()
            dur = self._now_ns() - t0
            with self._lock:
                tr.append(self._mkev(eid, name, "X", t0, dur, parent,
                                     rid, seq, args))

    # -- snapshot ------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Stable, JSON-serialisable dump (schema ``repro.trace/v1``).
        Per-track event order is each track's own (deterministic) order;
        tracks are never merged, so cross-thread interleaving can't make
        two identical runs dump differently."""
        with self._lock:
            tracks = {}
            for name in sorted(self._tracks):
                tr = self._tracks[name]
                tracks[name] = {
                    "dropped": tr.dropped,
                    "emitted": tr.emitted,
                    "events": tr.chronological(),
                }
        return {"schema": SCHEMA, "capacity": self.capacity,
                "tracks": tracks}

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True,
                          separators=(",", ":"))


# -- module-global tracer (mirrors telemetry / dejavulib.faults) --------
_ACTIVE: Optional[Tracer] = None


def install(t: Tracer) -> Optional[Tracer]:
    """Install *t* as the process-wide tracer; returns the previous one.
    Re-binds the owner thread to the installing thread."""
    global _ACTIVE
    prev = _ACTIVE
    t._owner = threading.get_ident()
    _ACTIVE = t
    return prev


def uninstall(prev: Optional[Tracer] = None) -> None:
    global _ACTIVE
    _ACTIVE = prev


def current() -> Optional[Tracer]:
    return _ACTIVE


def active() -> bool:
    """One-attribute-read gate hot call sites check before building args."""
    return _ACTIVE is not None


# -- cheap helpers: one `is None` check when tracing is off -------------
def event(name: str, **kw: object) -> None:
    t = _ACTIVE
    if t is not None:
        t.event(name, **kw)


@contextmanager
def span(name: str, **kw: object) -> Iterator[None]:
    t = _ACTIVE
    if t is None:
        yield
    else:
        with t.span(name, **kw):
            yield
