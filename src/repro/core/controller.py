"""DéjàVu controller: request coordination, heartbeats, failure recovery.

Implements the paper's §4.2.3 protocol:
  * workers send heartbeats; a missed deadline marks the worker failed;
  * replication acks (x, j, t) maintain the replication-status map;
  * 4-step recovery: (1) ring successor returns the failed worker's replica,
    (2) ring predecessor re-replicates its own KV to the new worker,
    (3) the controller finds the (microbatch, step) to re-execute from,
    (4) all stages resume from that point.

Beyond-paper: deadline-based straggler mitigation reuses the same machinery
(a slow worker is treated as failed-and-migrated), and elastic re-planning
rebuilds the stage partition via DéjàVuLib repartitioning.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class RequestRecord:
    rid: int
    prompt_len: int
    max_new: int
    tokens: List[int] = field(default_factory=list)   # emitted tokens
    done: bool = False
    submit_time: float = 0.0
    finish_time: float = 0.0


class Controller:
    def __init__(self, heartbeat_timeout: float = 2.0):
        self.heartbeat_timeout = heartbeat_timeout
        self.workers: List = []
        self.requests: Dict[int, RequestRecord] = {}
        # replication status: (worker_stage, microbatch) -> replicated step
        self.rep_status: Dict[Tuple[int, int], int] = {}
        self.events: List[dict] = []      # audit log (failures, recoveries)

    # ------------------------------------------------------------------
    def register(self, worker) -> None:
        self.workers.append(worker)

    def ack_replication(self, wid: int, mb: int, step: int) -> None:
        cur = self.rep_status.get((wid, mb), -1)
        if step > cur:
            self.rep_status[(wid, mb)] = step

    def replicated_step(self, wid: int, mb: int) -> int:
        return self.rep_status.get((wid, mb), -1)

    # ------------------------------------------------------------------
    def check_failures(self) -> List[int]:
        now = time.monotonic()
        dead = []
        for w in self.workers:
            if not w.alive or (now - w.last_heartbeat) > self.heartbeat_timeout:
                if not w.alive:
                    dead.append(w.wid)
        return dead

    def resume_point(self, failed_wid: int, active_mbs: List[int]) -> Dict[int, int]:
        """Step 3 of recovery: earliest non-replicated step per microbatch."""
        return {mb: self.replicated_step(failed_wid, mb) + 1 for mb in active_mbs}

    def log_event(self, kind: str, **kw) -> None:
        self.events.append({"kind": kind, "t": time.monotonic(), **kw})
