"""DéjàVu workers: one logical machine = one pipeline stage.

A `StageWorker` owns a contiguous layer slice of the model (jitted stage
functions), its device-resident KV slots, a host memory store (swap target +
prompt-KV landing zone), and a replica store holding its ring-predecessor's
KV copies (paper §4.2.3: worker x streams to worker (x+1)%N).

Failure semantics (paper): killing a worker loses BOTH its device KV and the
replica it hosts; `CacheManager` streams are how every byte moves (DéjàVuLib
primitives only — no ad-hoc copies).
"""
from __future__ import annotations

import functools
import math
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.core import tracing
from repro.core.dejavulib import (HostLinkTransport, HostMemoryStore,
                                  LocalTransport, NetworkTransport,
                                  StreamEngine)
from repro.core.dejavulib.transport import DEFAULT_HW, HardwareModel
from repro.kvcache.paged import (BlockPool, PagedKVCache, PoolExhausted,
                                 blocks_for)
from repro.kvcache.tiers import KVTierManager, TierConfig


class CacheManager:
    """Per-worker KV movement: swap in/out, replicate, receive (paper Fig. 5).

    `compress_replicas=True` (beyond-paper) int8-quantizes each replicated KV
    window (per-window scale) before it crosses the network and dequantizes
    into the peer's replica store — the wire bytes halve vs bf16 while the
    recovery path stays byte-layout-identical.  The quantization error only
    ever enters live state after an actual failure restore.
    """

    def __init__(self, wid: int, hw: HardwareModel, streamer: StreamEngine,
                 token_block: int = 8, compress_replicas: bool = False):
        self.wid = wid
        self.host = HostMemoryStore(f"w{wid}-host")        # swap + prompt landing
        self.replica = HostMemoryStore(f"w{wid}-replica")  # peer's KV copies
        self.hostlink = HostLinkTransport(hw)
        self.net = NetworkTransport(hw)
        self.local = LocalTransport(hw)
        self.streamer = streamer
        self.token_block = token_block
        self.compress_replicas = compress_replicas

    # --- swapping (microbatch granularity, paper §4.2.2) -------------------
    def swap_out(self, mb: int, kv: Dict[str, jax.Array],
                 token_range: Optional[Tuple[int, int]] = None) -> None:
        """Offload a microbatch's stage KV to host.  With `token_range`, only
        the newly-written window moves (buffered copies via kv_pack)."""
        from repro.kernels import ops as kops
        for leaf, arr in kv.items():
            key = f"swap/mb{mb}/{leaf}"
            if token_range is None:
                buf = self.hostlink.transfer(np.asarray(arr), tag=key)
                self.host.put(key, buf)     # transfer() copy is writable
                continue
            t0, t1 = token_range
            tb = self.token_block
            t0a = (t0 // tb) * tb
            w = min(-(-(t1 - t0a) // tb) * tb, arr.shape[2] - t0a)
            packed = np.asarray(kops.kv_pack_auto(arr, t0a, w, token_block=tb))
            self.hostlink.transfer(packed, tag=key)
            dense = self.host.get(key)          # update host copy in place
            dense[:, :, t0a:t0a + w] = packed
            self.host.put(key, dense)

    def swap_in(self, mb: int, shape, dtype) -> Dict[str, jax.Array]:
        out = {}
        for leaf in ("k", "v"):
            key = f"swap/mb{mb}/{leaf}"
            arr = self.host.get(key)
            self.hostlink.transfer(arr, tag=key)
            out[leaf] = jnp.asarray(arr)
        return out

    def host_has(self, mb: int) -> bool:
        return f"swap/mb{mb}/k" in self.host

    # --- replication (ring, token-level, paper §4.2.3) ----------------------
    def replicate_to(self, peer: "CacheManager", mb: int,
                     kv: Dict[str, jax.Array], token_range: Tuple[int, int],
                     step: int, ack_cb) -> None:
        """Stream the KV delta [t0,t1) to the ring successor's replica store.
        Runs on the background streamer (overlapped with the next step)."""
        from repro.kernels import ops as kops
        t0, t1 = token_range
        tb = self.token_block
        t0a = (t0 // tb) * tb
        packed = {}
        for leaf, arr in kv.items():
            w = min(-(-(t1 - t0a) // tb) * tb, arr.shape[2] - t0a)
            packed[leaf] = (np.asarray(kops.kv_pack_auto(arr, t0a, w, token_block=tb)),
                            arr.shape, arr.dtype)

        def _send():
            nbytes = 0
            for leaf, (buf, shape, dtype) in packed.items():
                key = f"w{self.wid}/mb{mb}/{leaf}"
                if self.compress_replicas:
                    scale = max(float(np.max(np.abs(buf))), 1e-8) / 127.0
                    q = np.clip(np.round(buf.astype(np.float32) / scale),
                                -127, 127).astype(np.int8)
                    sent = self.net.transfer(q, tag=key + "/int8")
                    recv = (sent.astype(np.float32) * scale).astype(dtype)
                else:
                    sent = self.net.transfer(buf, tag=key)
                    recv = sent
                if key in peer.replica:
                    dense = peer.replica.get(key)
                else:
                    dense = np.zeros(shape, dtype)
                dense[:, :, t0a:t0a + recv.shape[2]] = recv
                peer.replica.put(key, dense)
                nbytes += sent.nbytes
            ack_cb(self.wid, mb, step)
            return nbytes

        raw = sum(b.nbytes for b, _, _ in packed.values())
        model_s = self.net.model_time(raw // 2 if self.compress_replicas else raw)
        self.streamer.submit(_send, model_seconds=model_s,
                             tag=f"rep-w{self.wid}-mb{mb}-s{step}")

    # --- paged-mode movement (block granularity) ------------------------
    def replicate_block_to(self, peer: "CacheManager", seq: int, j: int,
                           arrays: Dict[str, np.ndarray], step: int,
                           ack_cb) -> None:
        """Stream ONE live KV block to the ring successor's replica store.
        Only the block touched this step crosses the wire (vs the dense
        path's token-window of a padded cache)."""
        def _send():
            nbytes = 0
            for leaf, arr in arrays.items():
                key = f"w{self.wid}/seq{seq}/blk{j}/{leaf}"
                if self.compress_replicas:
                    scale = max(float(np.max(np.abs(arr))), 1e-8) / 127.0
                    q = np.clip(np.round(arr.astype(np.float32) / scale),
                                -127, 127).astype(np.int8)
                    sent = self.net.transfer(q, tag=key + "/int8")
                    recv = (sent.astype(np.float32) * scale).astype(arr.dtype)
                else:
                    sent = self.net.transfer(arr, tag=key)
                    recv = sent
                peer.replica.put(key, np.array(recv))
                nbytes += sent.nbytes
            ack_cb(self.wid, seq, step)
            return nbytes

        raw = sum(a.nbytes for a in arrays.values())
        model_s = self.net.model_time(raw // 2 if self.compress_replicas else raw)
        self.streamer.submit(_send, model_seconds=model_s,
                             tag=f"rep-w{self.wid}-seq{seq}-blk{j}-s{step}")

    def replica_blocks(self, wid: int, seq: int) -> Dict[int, Dict[str, np.ndarray]]:
        """All replica blocks this store holds for (failed worker, seq)."""
        prefix = f"w{wid}/seq{seq}/blk"
        out: Dict[int, Dict[str, np.ndarray]] = {}
        for key in self.replica.keys():
            if key.startswith(prefix):
                j, leaf = key[len(prefix):].split("/")
                out.setdefault(int(j), {})[leaf] = self.replica.get(key)
        return out

    def swap_out_blocks(self, seq: int,
                        blocks: Dict[int, Dict[str, np.ndarray]]) -> int:
        """Offload the given (dirty) blocks of `seq` to host memory."""
        nbytes = 0
        for j, arrays in blocks.items():
            for leaf, arr in arrays.items():
                key = f"pagedswap/seq{seq}/blk{j}/{leaf}"
                buf = self.hostlink.transfer(arr, tag=key)
                self.host.put(key, buf)
                nbytes += buf.nbytes
        return nbytes

    def swap_in_blocks(self, seq: int) -> Dict[int, Dict[str, np.ndarray]]:
        prefix = f"pagedswap/seq{seq}/blk"
        out: Dict[int, Dict[str, np.ndarray]] = {}
        for key in self.host.keys():
            if key.startswith(prefix):
                j, leaf = key[len(prefix):].split("/")
                arr = self.host.get(key)
                self.hostlink.transfer(arr, tag=key)
                out.setdefault(int(j), {})[leaf] = arr
        return out

    def drop_seq_swap(self, seq: int) -> None:
        for key in [k for k in self.host.keys()
                    if k.startswith(f"pagedswap/seq{seq}/")]:
            self.host.delete(key)


class StageWorker:
    """One pipeline stage (a machine with `chips` accelerators running TP)."""

    def __init__(self, wid: int, model, full_params, lo: int, hi: int, *,
                 first: bool, last: bool, role: str = "both",
                 hw: HardwareModel = DEFAULT_HW,
                 streamer: Optional[StreamEngine] = None,
                 compress_replicas: bool = False):
        self.wid = wid
        self.model = model
        self.lo, self.hi = lo, hi
        self.first, self.last = first, last
        self.role = role                      # "prompt" | "token" | "both"
        self.alive = True
        self.hw = hw
        self.last_heartbeat = time.monotonic()
        self.sp = model.slice_params(full_params, lo, hi, first=first, last=last)
        self.kv: Dict[int, Dict[str, jax.Array]] = {}   # device-resident slots
        self.cache = CacheManager(wid, hw, streamer or StreamEngine(f"w{wid}"),
                                  compress_replicas=compress_replicas)
        self.slow_factor = 1.0                # straggler injection knob
        # paged mode (enable_paging): block pool + pages for this layer slice
        self.pool: Optional[BlockPool] = None
        self.pages: Optional[PagedKVCache] = None
        self.tier: Optional[KVTierManager] = None   # enable_tiering
        self.paged_dirty: Dict[int, set] = {}       # seq -> dirty logical blocks
        self.paged_swapped: Dict[int, int] = {}     # seq -> offloaded length

        mf = model
        if first:
            self._prefill = jax.jit(lambda sp, tokens: mf.stage_prefill(
                sp, None, first=True, last=last, tokens=tokens))
            self._decode = jax.jit(lambda sp, token, kc, vc, pos: mf.stage_decode(
                sp, None, kc, vc, pos, first=True, last=last, token=token))
            self._prefill_chunk = jax.jit(
                lambda sp, tokens, kc, vc, pos: mf.stage_prefill_chunk(
                    sp, None, kc, vc, pos, first=True, last=last, tokens=tokens))
            self._decode_batch = jax.jit(
                lambda sp, token, kc, vc, pos: mf.stage_decode_batch(
                    sp, None, kc, vc, pos, first=True, last=last, token=token))
            self._prefill_chunk_batch = jax.jit(
                lambda sp, tokens, kc, vc, pos, ql: mf.stage_prefill_chunk_batch(
                    sp, None, kc, vc, pos, ql, first=True, last=last,
                    tokens=tokens))
        else:
            self._prefill = jax.jit(lambda sp, x: mf.stage_prefill(
                sp, x, first=False, last=last))
            self._decode = jax.jit(lambda sp, x, kc, vc, pos: mf.stage_decode(
                sp, x, kc, vc, pos, first=False, last=last))
            self._prefill_chunk = jax.jit(
                lambda sp, x, kc, vc, pos: mf.stage_prefill_chunk(
                    sp, x, kc, vc, pos, first=False, last=last))
            self._decode_batch = jax.jit(
                lambda sp, x, kc, vc, pos: mf.stage_decode_batch(
                    sp, x, kc, vc, pos, first=False, last=last))
            self._prefill_chunk_batch = jax.jit(
                lambda sp, x, kc, vc, pos, ql: mf.stage_prefill_chunk_batch(
                    sp, x, kc, vc, pos, ql, first=False, last=last))

    # ------------------------------------------------------------------
    def heartbeat(self) -> bool:
        if self.alive:
            self.last_heartbeat = time.monotonic()
        return self.alive

    def kill(self) -> None:
        """Machine failure: device KV, host store, and hosted replica all die.
        The tier manager's host tier dies too; its SSD tier is disk and
        survives (recovery reattaches it on the replacement worker).

        Queued write-behinds are flushed before tier-1 state is wiped:
        already-issued DMA/disk writes complete even as the host dies (a
        transfer truly lost in flight is modeled by the transport ``drop``
        fault instead).  Without the flush a queued spill would observe the
        post-mortem empty host store and corrupt the tier index."""
        telemetry.count("worker.kills", 1, wid=self.wid)
        tracing.event("worker.kill", wid=self.wid)
        self.alive = False
        self.kv.clear()
        if (self.tier is not None
                and threading.current_thread() is not self.tier.streamer._thread):
            try:
                self.tier.streamer.drain()
            except Exception:
                # a write-behind racing the failure dies with the worker;
                # recovery must not trust its bytes (on_host_failure
                # re-verifies every on_ssd claim against the disk)
                pass
        self.cache.host.clear()
        self.cache.replica.clear()
        if self.tier is not None:
            self.tier.on_host_failure()

    def _check(self, op: Optional[str] = None, **ids: int):
        if not self.alive:
            raise RuntimeError(f"worker {self.wid} is dead")
        # every stage op (prefill/decode, paged or not) passes through here
        telemetry.count("worker.stage_calls", 1, wid=self.wid)
        if op is not None and tracing.active():
            # per-stage timeline: one track per worker, instants at the
            # modeled clock of the enclosing pass span
            tracing.event(f"stage.{op}", track=f"w{self.wid}", **ids)

    # ------------------------------------------------------------------
    def prefill(self, mb: int, x_or_tokens, max_len: int):
        self._check("prefill", mb=mb)
        if self.first:
            x, ks, vs = self._prefill(self.sp, x_or_tokens)
        else:
            x, ks, vs = self._prefill(self.sp, x_or_tokens)
        s = ks.shape[2]
        kc = jnp.zeros(ks.shape[:2] + (max_len,) + ks.shape[3:], ks.dtype)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, ks, 0, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vs, 0, axis=2)
        self.kv[mb] = {"k": kc, "v": vc}
        return x

    def decode(self, mb: int, x_or_token, pos: int):
        self._check("decode", mb=mb)
        slot = self.kv[mb]
        x, kc, vc = self._decode(self.sp, x_or_token, slot["k"], slot["v"],
                                 jnp.int32(pos))
        self.kv[mb] = {"k": kc, "v": vc}
        return x

    # --- swapping ------------------------------------------------------
    def offload(self, mb: int, token_range=None) -> None:
        if mb in self.kv:
            self.cache.swap_out(mb, self.kv[mb], token_range)
            del self.kv[mb]

    def restore(self, mb: int) -> None:
        if mb not in self.kv and self.cache.host_has(mb):
            self.kv[mb] = self.cache.swap_in(mb, None, None)

    def resident(self) -> int:
        return len(self.kv)

    def install_kv(self, mb: int, arrays: Dict[str, np.ndarray]) -> None:
        self.kv[mb] = {k: jnp.asarray(v) for k, v in arrays.items()}

    # ------------------------------------------------------------------
    # paged mode: per-sequence KV in ref-counted blocks (see kvcache.paged)
    # ------------------------------------------------------------------
    def enable_paging(self, num_blocks: int, block_size: int) -> None:
        cfg = self.model.cfg
        self.pool = BlockPool(num_blocks, block_size)
        self.pages = PagedKVCache(self.pool, layers=self.hi - self.lo,
                                  num_kv_heads=cfg.num_kv_heads,
                                  head_dim=cfg.resolved_head_dim,
                                  dtype=cfg.dtype)

    def enable_tiering(self, tier_cfg: TierConfig = TierConfig()) -> None:
        """Back this stage's pool with host-RAM and SSD tiers (see
        `repro.kvcache.tiers`): preemption swaps through the hierarchy,
        retired prompt blocks are demoted instead of dropped, and
        `adopt_prefix` promotes matching prefixes back for new requests."""
        assert self.paged, "enable_tiering requires enable_paging first"
        self.tier = KVTierManager(self.pool, self.pages, self.cache.streamer,
                                  hw=self.hw, cfg=tier_cfg,
                                  name=f"w{self.wid}")

    @property
    def paged(self) -> bool:
        return self.pool is not None

    def prefill_paged(self, seq: int, x_or_tokens, token_ids=None):
        """Stage prefill for ONE request (batch 1); KV lands in pool blocks.
        `token_ids` enables prefix-sharing of full prompt blocks."""
        self._check("prefill_paged", rid=seq)
        x, ks, vs = self._prefill(self.sp, x_or_tokens)
        s = ks.shape[2]
        _, fresh = self.pool.allocate(seq, s, token_ids=token_ids)
        # shared blocks already hold identical data (same prefix, same
        # weights); rewriting them is a no-op value-wise, so write the window
        # once instead of per-fresh-block bookkeeping
        self.pages.write_window(seq, {"k": np.asarray(ks[:, 0]),
                                      "v": np.asarray(vs[:, 0])}, 0)
        self.paged_dirty[seq] = {j for j, _, _, _ in self.pool.block_span(seq)}
        return x, len(fresh)

    def ensure_prefill_table(self, seq: int, plen: int, token_ids=None) -> None:
        """Size `seq`'s block table for the WHOLE prompt before chunked
        prefill: a cold prompt allocates fresh (with `token_ids`, full blocks
        whose prefix hash is live are ref-shared, like `prefill_paged` — but
        fresh blocks are NOT published until their pages are written, see
        `publish_prefix_hashes`); an adopted-prefix table (block-aligned,
        from `adopt_prefix`) is appended out to the full prompt length.
        Raises PoolExhausted before mutating."""
        self._check()
        if seq not in self.pool.tables:
            self.pool.allocate(seq, plen, token_ids=token_ids, publish=False)
            self.paged_dirty.setdefault(seq, set())
            return
        have = self.pool.seq_lens[seq]
        if plen > have:
            cow = self.pool.append(seq, plen - have)
            self.pages.apply_cow(cow)

    def publish_prefix_hashes(self, seq: int, hashes, upto_tokens: int) -> None:
        """Publish the prefix hashes of the prompt blocks whose pages are
        fully WRITTEN (the chunked-prefill cursor has passed them).  Blocks
        beyond the cursor stay unpublished so a concurrent allocate/adopt —
        or an abort-time demotion into the tier prefix cache — can never
        touch unwritten pages."""
        n = min(len(hashes), upto_tokens // self.pool.block_size)
        if n > 0:
            self.pool.publish_hashes(seq, hashes[:n])

    def prefill_chunk_paged(self, seq: int, x_or_tokens, pos0: int):
        """One chunk [pos0, pos0+C) of a paged prefill: densify the pool
        pages, run the chunked stage fn (the chunk attends over the resident
        prefix plus itself — `paged_prefill_attention` semantics), and
        scatter the chunk's K/V window back into its pages through kv_pack
        (DMA-aligned; the re-written head tokens of the aligned window hold
        identical values).  Requires `ensure_prefill_table` first."""
        self._check("prefill_chunk", rid=seq)
        c = int(x_or_tokens.shape[1])
        pad_to = len(self.pool.tables[seq]) * self.pool.block_size
        dense = self.pages.gather_dense(seq, pad_to)
        x, kc, vc = self._prefill_chunk(self.sp, x_or_tokens,
                                        jnp.asarray(dense["k"]),
                                        jnp.asarray(dense["v"]),
                                        jnp.int32(pos0))
        self._write_chunk_window(seq, kc, vc, pos0, c, pad_to)
        return x

    def _write_chunk_window(self, seq: int, kc, vc, pos0: int, c: int,
                            pad_to: int) -> None:
        """Scatter one chunk's K/V window [pos0, pos0+c) back into `seq`'s
        pages through a DMA-aligned kv_pack (kc/vc: [Lstage, 1, S, H, D]; the
        re-written head tokens of the aligned window hold identical values).
        Shared by the per-sequence and fused chunk paths so alignment and
        dirty-block accounting can never drift between them."""
        from repro.kernels import ops as kops
        bs = self.pool.block_size
        tb = self.cache.token_block
        t0a = (pos0 // tb) * tb
        w = min(-(-(pos0 + c - t0a) // tb) * tb, pad_to - t0a)
        # a pool whose block size does not divide the DMA token block can
        # clip the window off-alignment: shrink the copy granularity so the
        # pack still covers it exactly (t0a stays tb-aligned, so any divisor
        # of tb is a valid granularity)
        tbw = tb if w % tb == 0 else math.gcd(w, tb)
        win = {"k": np.asarray(kops.kv_pack_auto(kc, t0a, w, token_block=tbw))[:, 0],
               "v": np.asarray(kops.kv_pack_auto(vc, t0a, w, token_block=tbw))[:, 0]}
        self.pages.write_window(seq, win, t0a)
        self.paged_dirty.setdefault(seq, set()).update(
            range(t0a // bs, -(-(pos0 + c) // bs)))

    def decode_paged(self, seq: int, x_or_token, pos: int):
        """One decode step for one sequence: append a slot (CoW if the tail
        block is shared), gather blocks -> dense stage cache, run the jitted
        stage, scatter the new token's K/V back into its block."""
        self._check("decode_paged", rid=seq)
        cow = self.pool.append(seq)
        self.pages.apply_cow(cow)
        pad_to = len(self.pool.tables[seq]) * self.pool.block_size
        dense = self.pages.gather_dense(seq, pad_to)
        x, kc, vc = self._decode(self.sp, x_or_token, jnp.asarray(dense["k"]),
                                 jnp.asarray(dense["v"]), jnp.int32(pos))
        win = {"k": np.asarray(kc[:, 0, pos:pos + 1]),
               "v": np.asarray(vc[:, 0, pos:pos + 1])}
        self.pages.write_window(seq, win, pos)
        self.paged_dirty.setdefault(seq, set()).add(pos // self.pool.block_size)
        return x

    def _gather_batch(self, seqs) -> Tuple[jax.Array, jax.Array, int]:
        """Densify every sequence's pages to a common pad (ragged lengths
        over per-sequence block tables) -> (kc, vc, pad_to) with kc/vc
        [Lstage, B, pad_to, H, D] — the fused-round stage-cache layout."""
        pad_to = max(len(self.pool.tables[s]) for s in seqs) * self.pool.block_size
        dense = [self.pages.gather_dense(s, pad_to) for s in seqs]
        kc = jnp.asarray(np.concatenate([d["k"] for d in dense], axis=1))
        vc = jnp.asarray(np.concatenate([d["v"] for d in dense], axis=1))
        return kc, vc, pad_to

    def decode_paged_batch(self, seqs, x_or_tokens, poses):
        """ONE fused pipeline pass: every sequence in `seqs` decodes one step
        at its OWN position.  Appends a slot per sequence (CoW where shared),
        gathers the ragged block tables into a common-padded batch cache,
        runs the batched stage fn, and scatters each sequence's new-token K/V
        window back through one multi-sequence ragged buffered copy.  The
        cluster pre-flights pool capacity for the WHOLE batch first, so the
        per-sequence appends here cannot run out mid-batch."""
        self._check("decode_batch", n=len(seqs))
        from repro.kernels import ops as kops
        bs = self.pool.block_size
        for seq in seqs:
            cow = self.pool.append(seq)
            self.pages.apply_cow(cow)
        kc, vc, pad_to = self._gather_batch(seqs)
        pos = jnp.asarray(np.asarray(poses, np.int32))
        x, kc, vc = self._decode_batch(self.sp, x_or_tokens, kc, vc, pos)
        tb = self.cache.token_block
        t0s = [(p // tb) * tb for p in poses]
        if pad_to % tb == 0:
            # one ragged pack gathers every sequence's aligned one-token
            # window (vs B separate kv_pack launches); the aligned head
            # tokens re-write identical values, like the per-seq chunk path
            starts = np.asarray(t0s, np.int32)
            wk = np.asarray(kops.kv_pack_ragged_auto(kc, starts, tb,
                                                     token_block=tb))
            wv = np.asarray(kops.kv_pack_ragged_auto(vc, starts, tb,
                                                     token_block=tb))
            wins = [({"k": wk[:, i], "v": wv[:, i]}, t0s[i])
                    for i in range(len(seqs))]
        else:                            # unaligned pool blocks: plain slices
            kc_np, vc_np = np.asarray(kc), np.asarray(vc)
            wins = [({"k": kc_np[:, i, p:p + 1], "v": vc_np[:, i, p:p + 1]}, p)
                    for i, p in enumerate(poses)]
        for i, seq in enumerate(seqs):
            win, t0 = wins[i]
            self.pages.write_window(seq, win, t0)
            self.paged_dirty.setdefault(seq, set()).add(poses[i] // bs)
        return x

    def prefill_chunk_paged_batch(self, seqs, x_or_tokens, pos0s, q_lens):
        """One fused chunk-set pass: one prefill chunk of EACH sequence runs
        in a single pipeline pass (`stage_prefill_chunk_batch`), sequence i's
        chunk holding ``q_lens[i]`` valid tokens at positions ``pos0s[i]..``
        and attending over its own resident prefix plus itself.  Each
        sequence's K/V window scatters back into its own pages.  Requires
        `ensure_prefill_table` for every sequence first."""
        self._check("chunkset", n=len(seqs))
        kc, vc, pad_to = self._gather_batch(seqs)
        pos = jnp.asarray(np.asarray(pos0s, np.int32))
        ql = jnp.asarray(np.asarray(q_lens, np.int32))
        x, kc, vc = self._prefill_chunk_batch(self.sp, x_or_tokens, kc, vc,
                                              pos, ql)
        kc_np, vc_np = np.asarray(kc), np.asarray(vc)
        for i, seq in enumerate(seqs):
            self._write_chunk_window(seq, kc_np[:, i:i + 1], vc_np[:, i:i + 1],
                                     pos0s[i], q_lens[i], pad_to)
        return x

    def touched_block(self, seq: int, pos: int):
        """(logical_idx, arrays) of the block holding token `pos`."""
        j = pos // self.pool.block_size
        _, bid, t0, t1 = next(sp for sp in self.pool.block_span(seq)
                              if sp[0] == j)
        return j, self.pages.block_arrays(bid, width=t1 - t0)

    def live_blocks(self, seq: int) -> Dict[int, Dict[str, np.ndarray]]:
        return {j: self.pages.block_arrays(bid, width=t1 - t0)
                for j, bid, t0, t1 in self.pool.block_span(seq)}

    def install_blocks(self, seq: int, length: int,
                       blocks: Dict[int, Dict[str, np.ndarray]],
                       hashes=None) -> None:
        """(Re)build a sequence's pool entry from streamed blocks (recovery /
        swap-in / disaggregated prompt-KV landing).  With `hashes` (the
        sequence's prompt prefix chain) full prompt blocks already live in
        the pool are ref-shared instead of re-installed, so a recovered pool
        fits everything the failed one held."""
        if seq in self.pool.tables:
            self.pool.free_seq(seq)
        table, fresh = self.pool.allocate(seq, length, hashes=hashes)
        fresh_set = set(fresh)
        for j, bid in enumerate(table):
            if j in blocks and j in fresh_set:
                self.pages.install_block(bid, blocks[j])
        # shared blocks hold live data too: they must survive an offload
        self.paged_dirty[seq] = set(blocks) | (set(range(len(table)))
                                               - fresh_set)

    def paged_offload(self, seq: int) -> None:
        """Swap a sequence out: only dirty blocks cross the host link, then
        its pool blocks are freed (this is what admits more work).  With
        tiering enabled, the blocks enter the HBM→host→SSD hierarchy as
        write-behind instead of a plain host put."""
        if seq not in self.pool.tables:
            return
        dirty = self.paged_dirty.get(seq, set())
        blocks = {j: arrs for j, arrs in self.live_blocks(seq).items()
                  if j in dirty}
        if self.tier is not None:
            self.tier.swap_out_blocks(seq, blocks)
        else:
            self.cache.swap_out_blocks(seq, blocks)
        self.paged_swapped[seq] = self.pool.seq_lens[seq]
        self.pool.free_seq(seq)
        self.paged_dirty[seq] = set()

    def paged_restore(self, seq: int) -> None:
        if seq in self.pool.tables or seq not in self.paged_swapped:
            return
        length = self.paged_swapped[seq]
        # capacity check BEFORE any state mutation, so a failed restore is
        # retryable (the engine preempts a victim and calls again)
        if self.pool.num_free() < blocks_for(length, self.pool.block_size):
            raise PoolExhausted(
                f"worker {self.wid}: cannot restore seq {seq} "
                f"({blocks_for(length, self.pool.block_size)} blocks needed, "
                f"{self.pool.num_free()} free)")
        del self.paged_swapped[seq]
        blocks = (self.tier.swap_in_blocks(seq) if self.tier is not None
                  else self.cache.swap_in_blocks(seq))
        # clip: the held copy may extend past a rolled-back length
        keep = blocks_for(length, self.pool.block_size)
        self.install_blocks(seq, length,
                            {j: a for j, a in blocks.items() if j < keep})
        self.paged_dirty[seq] = set()

    def free_paged_seq(self, seq: int) -> None:
        """Retire a sequence.  With tiering, its hashed full prompt blocks
        are demoted into the prefix cache (write-behind) before the pool
        frees them — the seed of cross-request prefix reuse."""
        if self.pool is not None and seq in self.pool.tables:
            if self.tier is not None:
                self._demote_prefix_blocks(seq)
            self.pool.free_seq(seq)
        self.paged_swapped.pop(seq, None)
        self.paged_dirty.pop(seq, None)
        if self.tier is not None:
            self.tier.drop_seq(seq)
        self.cache.drop_seq_swap(seq)

    def _demote_prefix_blocks(self, seq: int) -> None:
        for j, bid, t0, t1 in self.pool.block_span(seq):
            h = self.pool.blocks[bid].hash
            if h is not None and not self.tier.has_prefix(h):
                self.tier.cache_prefix_block(h, self.pages.block_arrays(bid))

    # --- cross-request prefix reuse ------------------------------------
    def adoptable_prefix_len(self, hashes) -> int:
        """Longest leading run of prefix-chain hashes this stage can serve
        without prefill compute: live shared pool blocks OR any tier."""
        n = 0
        for h in hashes:
            if self.pool.has_hash(h) or \
                    (self.tier is not None and self.tier.has_prefix(h)):
                n += 1
            else:
                break
        return n

    def pool_prefix_hits(self, hashes) -> int:
        """Leading run servable by ref-sharing live pool blocks alone (these
        cost no free blocks — admission control's headroom discount)."""
        n = 0
        for h in hashes:
            if not self.pool.has_hash(h):
                break
            n += 1
        return n

    def adopt_prefix(self, seq: int, hashes, length: int) -> int:
        """Build `seq`'s prompt prefix from cached blocks: co-resident pool
        blocks are ref-shared; the rest are promoted out of the tier
        hierarchy.  Returns the number of tier-promoted blocks."""
        self._check("adopt_prefix", rid=seq)
        missing = [h for h in hashes if not self.pool.has_hash(h)]
        if len(missing) > self.pool.num_free():
            raise PoolExhausted(
                f"worker {self.wid}: adopting prefix for seq {seq} needs "
                f"{len(missing)} blocks, {self.pool.num_free()} free")
        fetched = (self.tier.fetch_prefix_chain(missing)
                   if missing and self.tier is not None else {})
        _, fills = self.pool.adopt_prefix(seq, hashes, length)
        for h, bid in fills:
            self.pages.install_block(bid, fetched[h])
        # adopted blocks count as dirty: the first offload must persist them
        # for this sequence (tier copies are keyed by hash, not by seq)
        self.paged_dirty[seq] = {j for j in range(len(hashes))}
        return len(fills)
