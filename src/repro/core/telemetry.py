"""Process-wide deterministic telemetry: counters, gauges, histograms, spans.

Every latency claim in the paper is a *distribution* claim (bubble
fraction, TTFT, inter-token p99, recovery time), so the serving stack
records them through one registry instead of per-feature trace lists.
The design constraints, in order:

1. **Determinism.**  Two identical runs must produce byte-identical
   snapshots.  Time-valued quantities that can be accumulated from the
   streamer thread are stored as *integer nanoseconds* (float addition
   is order-sensitive; integer addition is not).  The modeled clock is
   only ever advanced from the serving thread, and spans are only
   opened/closed there, so span timings are plain floats.
2. **Near-free when disabled.**  Instrumented code calls the module
   helpers (`count`, `observe`, `span`, ...) which are a single `is
   None` check when no registry is installed — the same pattern as
   `dejavulib.faults`.
3. **Bounded memory.**  Histograms keep fixed log-spaced buckets and a
   ns-sum, never raw samples; spans aggregate by path (count/total/max),
   never individual events.

The snapshot is a versioned, JSON-stable schema (``repro.telemetry/v1``)
consumed by ``EngineReport.telemetry``, ``benchmarks/common.py`` and
``tools/check_bench_trend.py``.
"""
from __future__ import annotations

import json
import math
import threading
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, Tuple

SCHEMA = "repro.telemetry/v1"

# Default histogram bucket upper bounds, seconds.  Log-spaced from 1 us
# to 10 min: 4 buckets per decade is plenty for p50/p90/p99 bands while
# keeping snapshots small.  Samples above the last edge land in a final
# overflow bucket.
_DECADES = range(-6, 3)  # 1e-6 .. 1e2
DEFAULT_BUCKETS_S: Tuple[float, ...] = tuple(
    round(m * (10.0 ** d), 12) for d in _DECADES for m in (1.0, 2.0, 5.0)
) + (600.0,)

_NS = 1_000_000_000


def _ns(seconds: float) -> int:
    return int(round(seconds * _NS))


def _label_key(name: str, labels: Dict[str, object]) -> str:
    if not labels:
        return name
    parts = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{parts}}}"


class Counter:
    """Monotonic integer counter (time counters accumulate nanoseconds)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, v: int = 1) -> None:
        self.value += int(v)


class Gauge:
    """Last-write-wins float value (set from the serving thread only)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: bucket counts + ns-sum + min/max.

    Quantiles are computed from bucket counts by linear interpolation
    inside the containing bucket, clamped to the observed [min, max] —
    deterministic, and never stores raw samples.
    """

    __slots__ = ("buckets", "counts", "count", "sum_ns", "min", "max")

    def __init__(self, buckets: Tuple[float, ...] = DEFAULT_BUCKETS_S) -> None:
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)  # +1 overflow bucket
        self.count = 0
        self.sum_ns = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.count += 1
        self.sum_ns += _ns(v)
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c >= target:
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                hi = self.buckets[i] if i < len(self.buckets) else self.max
                frac = (target - cum) / c
                v = lo + (hi - lo) * frac
                return min(max(v, self.min), self.max)
            cum += c
        return self.max


class Telemetry:
    """The registry: typed instruments plus the modeled clock.

    Instruments are keyed by ``name`` or ``name{k=v,...}`` (labels
    sorted).  All mutation goes through a lock; the hot-path cost is one
    dict lookup + one int add.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        # span path -> [count, total_s, max_s]
        self._spans: Dict[str, List[float]] = {}
        self._tls = threading.local()
        self.clock_s = 0.0
        # determinism rule: the modeled clock and spans belong to the
        # thread that owns the registry (bound here, re-bound by
        # ``install``); `_check_owner` enforces what PR 7 documented
        self._owner = threading.get_ident()

    def _check_owner(self, what: str) -> None:
        if threading.get_ident() != self._owner:
            raise RuntimeError(
                f"Telemetry.{what} called from a non-owner thread; the "
                "modeled clock and spans are serving-thread only — use "
                "count/count_time (integer-ns) from background threads")

    # -- modeled clock (serving thread only) ---------------------------
    def advance(self, dt: float) -> None:
        self._check_owner("advance")
        if dt > 0.0:
            self.clock_s += dt

    # -- instruments ---------------------------------------------------
    def count(self, name: str, v: int = 1, **labels: object) -> None:
        key = _label_key(name, labels)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter()
            c.inc(v)

    def count_time(self, name: str, seconds: float, **labels: object) -> None:
        """Accumulate a duration as integer ns (thread-order independent)."""
        self.count(name, _ns(seconds), **labels)

    def gauge(self, name: str, v: float, **labels: object) -> None:
        key = _label_key(name, labels)
        with self._lock:
            g = self._gauges.get(key)
            if g is None:
                g = self._gauges[key] = Gauge()
            g.set(v)

    def observe(self, name: str, seconds: float, **labels: object) -> None:
        key = _label_key(name, labels)
        with self._lock:
            h = self._histograms.get(key)
            if h is None:
                h = self._histograms[key] = Histogram()
            h.observe(seconds)

    # -- spans (serving thread only; timed on the modeled clock) -------
    def _stack(self) -> List[str]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    @contextmanager
    def span(self, name: str, **tags: object) -> Iterator[None]:
        self._check_owner("span")
        label = name
        if tags:
            label += "[" + ",".join(f"{k}={tags[k]}" for k in sorted(tags)) + "]"
        stack = self._stack()
        stack.append(label)
        path = "/".join(stack)
        t0 = self.clock_s
        try:
            yield
        finally:
            dt = self.clock_s - t0
            stack.pop()
            with self._lock:
                rec = self._spans.get(path)
                if rec is None:
                    rec = self._spans[path] = [0, 0.0, 0.0]
                rec[0] += 1
                rec[1] += dt
                if dt > rec[2]:
                    rec[2] = dt

    # -- snapshot ------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Stable, JSON-serialisable snapshot (schema ``repro.telemetry/v1``)."""
        with self._lock:
            counters = {k: c.value for k, c in sorted(self._counters.items())}
            gauges = {k: g.value for k, g in sorted(self._gauges.items())}
            hists = {}
            for k, h in sorted(self._histograms.items()):
                hists[k] = {
                    "buckets_s": list(h.buckets),
                    "count": h.count,
                    "counts": list(h.counts),
                    "max_s": h.max if h.count else 0.0,
                    "min_s": h.min if h.count else 0.0,
                    "p50_s": h.quantile(0.50),
                    "p90_s": h.quantile(0.90),
                    "p99_s": h.quantile(0.99),
                    "sum_s": h.sum_ns / _NS,
                }
            spans = {
                k: {"count": int(rec[0]), "max_s": rec[2], "total_s": rec[1]}
                for k, rec in sorted(self._spans.items())
            }
        return {
            "schema": SCHEMA,
            "clock_s": self.clock_s,
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
            "spans": spans,
        }

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, separators=(",", ":"))


# -- module-global registry (mirrors dejavulib.faults) -----------------
_ACTIVE: Optional[Telemetry] = None


def install(t: Telemetry) -> Optional[Telemetry]:
    """Install *t* as the process-wide registry; returns the previous one.
    Re-binds the clock/span owner to the installing thread."""
    global _ACTIVE
    prev = _ACTIVE
    t._owner = threading.get_ident()
    _ACTIVE = t
    return prev


def uninstall(prev: Optional[Telemetry] = None) -> None:
    global _ACTIVE
    _ACTIVE = prev


def current() -> Optional[Telemetry]:
    return _ACTIVE


def active() -> bool:
    return _ACTIVE is not None


# -- cheap helpers: one `is None` check when telemetry is off ----------
def count(name: str, v: int = 1, **labels: object) -> None:
    t = _ACTIVE
    if t is not None:
        t.count(name, v, **labels)


def count_time(name: str, seconds: float, **labels: object) -> None:
    t = _ACTIVE
    if t is not None:
        t.count(name, _ns(seconds), **labels)


def observe(name: str, seconds: float, **labels: object) -> None:
    t = _ACTIVE
    if t is not None:
        t.observe(name, seconds, **labels)


def gauge(name: str, v: float, **labels: object) -> None:
    t = _ACTIVE
    if t is not None:
        t.gauge(name, v, **labels)


def advance(dt: float) -> None:
    t = _ACTIVE
    if t is not None:
        t.advance(dt)


def clock() -> float:
    t = _ACTIVE
    return t.clock_s if t is not None else 0.0


@contextmanager
def span(name: str, **tags: object) -> Iterator[None]:
    t = _ACTIVE
    if t is None:
        yield
    else:
        with t.span(name, **tags):
            yield
