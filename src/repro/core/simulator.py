"""Cluster simulator for the planner studies (paper Appendix B) and the
failure experiments (Figs. 14–15).

Three serving policies, matching the paper exactly:
  Baseline      — one TP+PP pipeline of depth D; every stage does P and T
  Baseline-DP   — d independent pipelines of depth D/d (round-robin jobs)
  DéjàVu        — disaggregated: prompt pipeline depth D_p + token pipeline
                  depth D_t, prompt KV streamed P→T (overlap-adjusted)

The generated-token distribution follows an LMSys-like long-tailed lognormal
(the real dataset is not redistributable offline; parameters are matched to
its published summary stats — see benchmarks/planner_study.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import costmodel as cm
from repro.core.dejavulib.transport import DEFAULT_HW, HardwareModel
from repro.core.planner import MachineSpec, Plan, plan
from repro.core.schedule import EventEngine, Job, build_pipeline_items, rr_schedule


def lmsys_like_tokens(n: int, seed: int = 0, mean_target: float = 220.0,
                      sigma: float = 1.1, max_tokens: int = 1024) -> np.ndarray:
    """Long-tailed generated-token counts (deterministic given seed)."""
    rng = np.random.default_rng(seed)
    mu = np.log(mean_target) - sigma ** 2 / 2
    x = rng.lognormal(mu, sigma, size=n)
    return np.clip(x, 8, max_tokens).astype(int)


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed + 1)
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)


@dataclass
class SimResult:
    makespan: float
    per_mb_finish: dict
    normalized_latency: float       # median s/token over microbatches
    policy: str

    def cost(self, n_machines: int, hourly: float = 1.0) -> float:
        return self.makespan / 3600.0 * n_machines * hourly


def _norm_latency(trace, jobs, pipeline: str, depth: int, arrivals) -> float:
    vals = []
    for job in jobs:
        key = (pipeline, job.mb, "T", job.n_tokens - 1, depth - 1)
        if key in trace.finish:
            lat = trace.finish[key] - arrivals[job.mb]
            vals.append(lat / job.n_tokens)
    return float(np.median(vals)) if vals else float("nan")


def simulate_baseline(cfg: ArchConfig, wl: cm.WorkloadSpec, d: int,
                      jobs: List[Job], mach: MachineSpec = MachineSpec(),
                      hw: HardwareModel = DEFAULT_HW, mfu=0.5, beff=0.7,
                      swapping: bool = False) -> SimResult:
    lps = -(-cfg.num_layers // d)  # layers per stage
    ctx = wl.prompt_len + wl.new_tokens
    y_s = cm.stage_prompt_time(cfg, wl, lps, mach.chips, hw, mfu)
    t_s = cm.stage_token_time(cfg, wl, lps, mach.chips, ctx, hw, beff)
    if swapping:
        t_s = max(t_s, cm.swap_transfer_time(cfg, wl, lps, ctx, hw))
    tr, _ = rr_schedule(jobs, pipeline="main", depth=d, p_dur=y_s, t_dur=t_s)
    arrivals = {j.mb: j.arrival for j in jobs}
    return SimResult(tr.makespan, dict(tr.finish),
                     _norm_latency(tr, jobs, "main", d, arrivals), "baseline")


def simulate_dp(cfg: ArchConfig, wl: cm.WorkloadSpec, d: int, n_pipelines: int,
                jobs: List[Job], mach: MachineSpec = MachineSpec(),
                hw: HardwareModel = DEFAULT_HW, mfu=0.5, beff=0.7) -> SimResult:
    depth = d // n_pipelines
    assert depth >= 1
    lps = -(-cfg.num_layers // depth)
    ctx = wl.prompt_len + wl.new_tokens
    y_s = cm.stage_prompt_time(cfg, wl, lps, mach.chips, hw, mfu)
    t_s = cm.stage_token_time(cfg, wl, lps, mach.chips, ctx, hw, beff)
    buckets: List[List[Job]] = [[] for _ in range(n_pipelines)]
    for i, j in enumerate(jobs):
        buckets[i % n_pipelines].append(j)
    makespan, vals, finishes = 0.0, [], {}
    arrivals = {j.mb: j.arrival for j in jobs}
    for pi, bucket in enumerate(buckets):
        tr, _ = rr_schedule(bucket, pipeline=f"dp{pi}", depth=depth,
                            p_dur=y_s, t_dur=t_s)
        makespan = max(makespan, tr.makespan)
        finishes.update(tr.finish)
        for job in bucket:
            key = (f"dp{pi}", job.mb, "T", job.n_tokens - 1, depth - 1)
            if key in tr.finish:
                vals.append((tr.finish[key] - arrivals[job.mb]) / job.n_tokens)
    return SimResult(makespan, finishes,
                     float(np.median(vals)) if vals else float("nan"), "baseline-dp")


def simulate_dejavu(cfg: ArchConfig, wl: cm.WorkloadSpec, d: int, jobs: List[Job],
                    mach: MachineSpec = MachineSpec(),
                    hw: HardwareModel = DEFAULT_HW, mfu=0.5, beff=0.7,
                    the_plan: Optional[Plan] = None,
                    swapping: bool = False) -> SimResult:
    p = the_plan or plan(cfg, wl, d, mach, hw, mfu, beff)
    if not p.feasible:
        return SimResult(float("inf"), {}, float("inf"), "dejavu")
    dp, dt = p.d_prompt, p.d_token
    ctx = wl.prompt_len + wl.new_tokens
    lp_p = -(-cfg.num_layers // dp)
    lp_t = -(-cfg.num_layers // dt)
    y_s = cm.stage_prompt_time(cfg, wl, lp_p, mach.chips, hw, mfu)
    t_s = cm.stage_token_time(cfg, wl, lp_t, mach.chips, ctx, hw, beff)
    if swapping:
        t_s = max(t_s, cm.swap_transfer_time(cfg, wl, lp_t, ctx, hw))
    stream = cm.prompt_kv_stream_time(cfg, wl, hw)
    exposed_stream = max(0.0, stream - y_s) * 0.1  # layer-wise overlap hides ~90%

    # prompt pipeline (P only), then token pipeline gated on handoff
    tr_p, _ = rr_schedule(jobs, pipeline="prompt", depth=dp, p_dur=y_s,
                          t_dur=0.0, do_tokens=False)
    gate = {j.mb: tr_p.finish[("prompt", j.mb, "P", 0, dp - 1)] + exposed_stream
            for j in jobs}
    tr_t, _ = rr_schedule(jobs, pipeline="token", depth=dt, p_dur=0.0,
                          t_dur=t_s, do_prompt=False, token_gate=gate)
    finishes = {**tr_p.finish, **tr_t.finish}
    arrivals = {j.mb: j.arrival for j in jobs}
    makespan = max(tr_p.makespan, tr_t.makespan)
    nl = _norm_latency(tr_t, jobs, "token", dt, arrivals)
    return SimResult(makespan, finishes, nl, "dejavu")


# ---------------------------------------------------------------------------
# Failure modeling (Figs. 14–15): latency inflation of in-flight microbatches
# ---------------------------------------------------------------------------

def failure_latency(cfg: ArchConfig, wl: cm.WorkloadSpec, d: int,
                    fail_step: int, *, dejavu: bool,
                    mach: MachineSpec = MachineSpec(),
                    hw: HardwareModel = DEFAULT_HW,
                    detect_s: float = 1.0, restart_s: float = 30.0,
                    replication_lag: int = 1, mfu=0.5, beff=0.7) -> dict:
    """Cumulative latency of one microbatch when a stage fails at token
    `fail_step`.  Baseline restarts the request from scratch (prompt + all
    tokens); DéjàVu resumes from the last replicated step."""
    lps = -(-cfg.num_layers // d)
    ctx = wl.prompt_len + wl.new_tokens
    y_s = cm.stage_prompt_time(cfg, wl, lps, mach.chips, hw, mfu) * d
    t_s = cm.stage_token_time(cfg, wl, lps, mach.chips, ctx, hw, beff) * d
    n = wl.new_tokens
    no_fail = y_s + n * t_s
    pre = y_s + fail_step * t_s
    if dejavu:
        # restore = fetch replica of the failed stage's KV (host->device)
        kv_bytes = cfg.decode_state_bytes(wl.prompt_len + fail_step) * \
            wl.microbatch / d
        restore = kv_bytes / hw.host_link_bw + kv_bytes / hw.dcn_stream_bw
        redo = replication_lag * t_s
        total = pre + detect_s + restore + redo + (n - fail_step) * t_s
    else:
        total = pre + detect_s + restart_s + no_fail
    return {"no_fail_s": no_fail, "with_fail_s": total,
            "slowdown": total / no_fail}
