"""In-process DéjàVu cluster: real pipeline-parallel serving with prompt/token
disaggregation, microbatch swapping, ring replication, failure recovery,
straggler migration, and elastic repartitioning.

Workers are real objects holding real arrays; every byte between them moves
through DéjàVuLib primitives over modeled transports, so tests assert on
actual tokens while benchmarks read the modeled transfer timelines.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import costmodel as cm
from repro.core import telemetry
from repro.core import tracing
from repro.core.controller import Controller
from repro.core.dejavulib import (NetworkTransport, PipelineTopo, StreamEngine,
                                  faults, stream_in, stream_in_blocks,
                                  stream_out, stream_out_blocks)
from repro.core.dejavulib.transport import DEFAULT_HW, HardwareModel
from repro.core.worker import StageWorker
from repro.kvcache.paged import BlockPool, PoolExhausted, blocks_for
from repro.kvcache.tiers import TierConfig


def _stage_ranges(num_layers: int, depth: int) -> List[Tuple[int, int]]:
    assert depth <= num_layers, f"pipeline depth {depth} > {num_layers} layers"
    splits = np.array_split(np.arange(num_layers), depth)
    return [(int(s[0]), int(s[-1]) + 1) for s in splits]


def fused_supported(cfg: ArchConfig) -> bool:
    """Whether the batched fused-round path is EXACT for this config — the
    narrow correctness gate behind `DejaVuCluster.fused_ok`.

    The batched mask/bias path carries full-causal, ALiBi, and
    sliding-window(+meta attention-sink) attention per sequence, so every
    dense/moe config qualifies.  What it cannot express is per-sequence
    state outside the KV cache: ssm/hybrid recurrent state, encdec
    cross-attention, and vlm patch slots (`num_patches` shifts every token's
    cache position by a per-request prefix the batched gather does not
    carry) — those families fall back to the per-sequence oracle path
    cleanly, fused knob or not.  Mirrored by
    `costmodel.fused_round_supported` so planner round terms degrade the
    same way."""
    return cfg.family in ("dense", "moe") and not cfg.num_patches


class DejaVuCluster:
    def __init__(self, cfg: ArchConfig, model, params, n_workers: int, *,
                 mode: str = "colocated", dp_split: Optional[Tuple[int, int]] = None,
                 swapping: bool = False, replication: bool = False,
                 compress_replicas: bool = False,
                 max_resident: int = 2, hw: HardwareModel = DEFAULT_HW,
                 paged: bool = False, kv_block_size: Optional[int] = None,
                 kv_pool_blocks: Optional[int] = None,
                 tiered: bool = False,
                 host_cache_blocks: Optional[int] = None,
                 ssd_cache_blocks: Optional[int] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 fused_rounds: Optional[bool] = None):
        assert mode in ("colocated", "disaggregated")
        if mode == "disaggregated":
            assert dp_split is not None and sum(dp_split) == n_workers
        if tiered:
            assert paged, "tiered=True requires paged=True"
        self.cfg = cfg
        self.model = model
        self.params = params             # full weights (the checkpoint store)
        self.mode = mode
        self.swapping = swapping
        self.replication = replication
        self.compress_replicas = compress_replicas
        self.max_resident = max_resident
        self.hw = hw
        self.paged = paged
        self.tiered = tiered
        self.tier_cfg = TierConfig(host_capacity_blocks=host_cache_blocks,
                                   ssd_capacity_blocks=ssd_cache_blocks)
        self.kv_block_size = kv_block_size or cfg.kv_block_size
        self.kv_pool_blocks = kv_pool_blocks or cfg.kv_pool_blocks or 512
        self.prefill_chunk_tokens = (cfg.prefill_chunk_tokens
                                     if prefill_chunk_tokens is None
                                     else prefill_chunk_tokens)
        self.fused_rounds = (cfg.fused_rounds if fused_rounds is None
                             else fused_rounds)
        self.streamer = StreamEngine("cluster")
        self.controller = Controller()
        self.net = NetworkTransport(hw)

        if mode == "colocated":
            self.prompt_group = self.token_group = self._build_group(
                n_workers, role="both", wid0=0)
        else:
            dp, dt = dp_split
            self.prompt_group = self._build_group(dp, role="prompt", wid0=0)
            self.token_group = self._build_group(dt, role="token", wid0=dp)
        for w in set(self.prompt_group + self.token_group):
            self.controller.register(w)
            if paged:
                w.enable_paging(self.kv_pool_blocks, self.kv_block_size)
                if tiered:
                    w.enable_tiering(self.tier_cfg)
        self.mb_pos: Dict[int, int] = {}        # current KV length per microbatch
        self.mb_prompt_len: Dict[int, int] = {}
        self.mb_max_len: Dict[int, int] = {}
        self.mb_batch: Dict[int, int] = {}
        # paged (per-sequence) bookkeeping
        self.seq_len: Dict[int, int] = {}       # live tokens per sequence
        self.seq_prompt_len: Dict[int, int] = {}
        self.seq_hashes: Dict[int, List[int]] = {}   # prompt prefix chain
        self.kv_bytes_peak = 0
        # cross-request prefix-reuse accounting (tiered mode)
        self.prefill_tokens_total = 0
        self.prefill_tokens_saved = 0
        self.prefix_hit_blocks = 0
        # chunked-prefill accounting + in-flight (engine-interleaved) state
        self._pending_prefill: Dict[int, dict] = {}
        self.prefill_passes: Dict[int, int] = {}     # rid -> passes last prefill
        self.adoption_suffix_log: List[Tuple[int, int]] = []  # (suffix_toks, passes)
        self.round_prefill_model_s = 0.0   # modeled prefill s this round (engine resets)
        # telemetry clock marks of delivered kills; the engine closes each
        # into a `cluster.recovery_s` observation at the first token emitted
        # after the restore (paper: fail -> first post-restore token)
        self._recovery_marks: List[float] = []

    # ------------------------------------------------------------------
    def live_kv_bytes(self) -> int:
        """Device-resident decode-state bytes right now (dense slots + pages)."""
        total = 0
        for w in set(self.prompt_group + self.token_group):
            if w.paged:
                total += w.pages.used_bytes()
            for slot in w.kv.values():
                total += sum(int(np.prod(a.shape)) * a.dtype.itemsize
                             for a in slot.values())
        return total

    def _track_kv_peak(self) -> None:
        self.kv_bytes_peak = max(self.kv_bytes_peak, self.live_kv_bytes())

    # ------------------------------------------------------------------
    def _build_group(self, depth: int, role: str, wid0: int) -> List[StageWorker]:
        ranges = _stage_ranges(self.cfg.num_layers, depth)
        ws = []
        for i, (lo, hi) in enumerate(ranges):
            ws.append(StageWorker(wid0 + i, self.model, self.params, lo, hi,
                                  first=(i == 0), last=(i == len(ranges) - 1),
                                  role=role, hw=self.hw, streamer=self.streamer,
                                  compress_replicas=self.compress_replicas))
        return ws

    def _topo(self, group: List[StageWorker]) -> PipelineTopo:
        return PipelineTopo(depth=len(group), num_layers=self.cfg.num_layers,
                            microbatch=0)

    # ------------------------------------------------------------------
    # serving primitives
    # ------------------------------------------------------------------
    def prefill_mb(self, mb: int, tokens: jnp.ndarray, max_new: int) -> jnp.ndarray:
        """Prefill a microbatch through the prompt pipeline; in disaggregated
        mode, stream its prompt KV to the token pipeline (paper §4.2.1)."""
        b, plen = tokens.shape
        # cache length aligned to the kv_pack DMA token block (8)
        max_len = -(-(plen + max_new) // 8) * 8
        self.mb_batch[mb] = b
        self.mb_pos[mb] = plen
        self.mb_prompt_len[mb] = plen
        self.mb_max_len[mb] = max_len
        with telemetry.span("pass", kind="mb_prefill"), \
                tracing.span("pass", kind="mb_prefill", mb=mb, batch=b):
            x = tokens
            for w in self.prompt_group:
                x = w.prefill(mb, x, max_len)
            telemetry.advance(cm.stage_prompt_time(
                self.cfg, cm.WorkloadSpec(prompt_len=plen, new_tokens=1,
                                          microbatch=b),
                self.cfg.num_layers, 8, self.hw))
        logits = x
        if self.mode == "disaggregated":
            self._stream_prompt_kv(mb, plen)
        if self.replication:
            self._replicate(mb, (0, plen), step=0, group=self.token_group)
        if self.swapping:
            for w in self.token_group:
                if mb in w.kv:
                    w.offload(mb)           # full first offload to host
        self._track_kv_peak()
        return logits

    def _stream_prompt_kv(self, mb: int, plen: int) -> None:
        bsz = self.mb_batch[mb]
        topo_p = PipelineTopo(len(self.prompt_group), self.cfg.num_layers, bsz)
        topo_t = PipelineTopo(len(self.token_group), self.cfg.num_layers, bsz)
        dst_stores = {i: w.cache.host for i, w in enumerate(self.token_group)}
        for si, w in enumerate(self.prompt_group):
            kv = w.kv.pop(mb)
            state = {"kv": {k: np.asarray(v) for k, v in kv.items()}}
            mbk = f"{mb}"
            stream_out(state, si, topo_p, topo_t, dst_stores, self.net,
                       mb=mbk, token_range=(0, plen))
        # token side: merge chunks into local caches sized max_len
        b = None
        for di, w in enumerate(self.token_group):
            lo, hi = topo_t.layer_range(di)
            hkv, dh = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
            # batch size from any incoming chunk
            some_key = next(k for k in w.cache.host.keys() if k.startswith(f"mb{mb}/kv/"))
            b = w.cache.host.get(some_key).shape[1]
            shapes = {"kv": {"k": ((hi - lo, b, self.mb_max_len[mb], hkv, dh), self.cfg.dtype),
                             "v": ((hi - lo, b, self.mb_max_len[mb], hkv, dh), self.cfg.dtype)}}
            local = stream_in(w.cache.host, di, topo_t, topo_p, shapes, self.net,
                              mb=f"{mb}", token_range=(0, plen))
            w.install_kv(mb, local["kv"])
            for key in [k for k in w.cache.host.keys() if k.startswith(f"mb{mb}/")]:
                w.cache.host.delete(key)

    def decode_mb(self, mb: int, token: jnp.ndarray, step: int) -> jnp.ndarray:
        """One decode step through the token pipeline.  Returns logits [B,V].
        `step` is 1-based (step i consumes token_{i-1})."""
        pos = self.mb_pos[mb]
        with telemetry.span("pass", kind="mb_decode"), \
                tracing.span("pass", kind="mb_decode", mb=mb, step=step):
            if self.swapping:
                for w in self.token_group:
                    w.restore(mb)
            x = token
            for w in self.token_group:
                x = w.decode(mb, x, pos)
            telemetry.advance(cm.stage_token_time(
                self.cfg, cm.WorkloadSpec(prompt_len=max(pos, 1), new_tokens=1,
                                          microbatch=self.mb_batch.get(mb, 1)),
                self.cfg.num_layers, 8, pos + 1, self.hw))
        self.mb_pos[mb] = pos + 1
        if self.replication:
            self._replicate(mb, (pos, pos + 1), step=step, group=self.token_group)
        if self.swapping:
            for w in self.token_group:
                w.offload(mb, token_range=(pos, pos + 1))
        for w in set(self.prompt_group + self.token_group):
            w.heartbeat()
        self._track_kv_peak()
        return x

    # ------------------------------------------------------------------
    # paged serving primitives (continuous batching; KV moves per BLOCK)
    # ------------------------------------------------------------------
    @property
    def fused_ok(self) -> bool:
        """Fused batched rounds run whenever the knob is on, the cluster is
        paged, and `fused_supported` says the batched mask/bias path is
        exact for the family — ALiBi (bloom) and sliding-window+meta (hymba
        -style dense mixes) included.  Unsupported families (ssm / hybrid /
        encdec / vlm) fall back to the per-sequence oracle path cleanly."""
        return self.fused_rounds and self.paged and fused_supported(self.cfg)

    def can_admit(self, prompt_len: int, n_active: int,
                  token_ids: Optional[np.ndarray] = None) -> bool:
        """Admission control: every token-side pool must fit the prompt plus
        one headroom block per already-running sequence (each may need a new
        block before this request finishes its first step).

        With tiering, `token_ids` lets admission count cached capacity: full
        prompt blocks whose prefix hash is live in a pool will be ref-shared,
        not allocated, so they need no free blocks — in BOTH serving modes
        (the prompt side adopts prefixes during prefill; the token side
        re-shares them when the streamed blocks install).  The chain is
        capped one block short of the prompt — at least one suffix token must
        run through compute — so a boundary-aligned prompt's last full block
        is never discounted.  Tier-backed blocks still promote INTO free
        blocks and are not discounted.  In disaggregated mode the prompt-side
        pools are checked too (they hold the prompt only until its blocks
        stream out, so no per-active headroom there)."""
        bs = self.kv_block_size
        need = blocks_for(prompt_len + 1, bs) + n_active
        hashes: List[int] = []
        if token_ids is not None and self.tiered:
            hashes = BlockPool.chain_hashes(
                [int(t) for t in token_ids], bs)[:(prompt_len - 1) // bs]

        def fits(w: StageWorker, want: int) -> bool:
            hits = w.pool_prefix_hits(hashes) if hashes else 0
            return w.pool.num_free() >= want - hits

        if not all(fits(w, need) for w in self.token_group):
            return False
        if self.mode == "disaggregated":
            pneed = blocks_for(prompt_len, bs)
            return all(fits(w, pneed) for w in self.prompt_group)
        return True

    def prefill_seq(self, rid: int, prompt: np.ndarray, max_new: int) -> jnp.ndarray:
        """Prefill ONE request through the prompt pipeline into pool blocks,
        running every pipeline pass back-to-back (the engine's interleaved
        scheduler calls `prefill_seq_begin`/`prefill_seq_step` itself so
        decode steps can run between chunks)."""
        self.prefill_seq_begin(rid, prompt, max_new)
        logits = None
        while logits is None:
            logits = self.prefill_seq_step(rid)
        return logits

    def _chunkable(self) -> bool:
        """Chunked prefill is exact only where the decode path is (same
        restriction as prefix adoption): dense/moe attention — the chunk
        mask carries windows, meta sinks, and ALiBi per sequence; only vlm
        patch slots (a per-request position prefix) are out."""
        return (self.prefill_chunk_tokens > 0
                and self.cfg.family in ("dense", "moe")
                and not self.cfg.num_patches)

    def prefill_seq_begin(self, rid: int, prompt: np.ndarray,
                          max_new: int) -> None:
        """Stage a prefill for `prefill_seq_step` to advance pass by pass.
        With tiering, the prompt's prefix-chain hashes are first matched
        against live pool blocks AND the host/SSD tiers of every prompt-side
        stage; a matching prefix is adopted (streamed back up the hierarchy)
        and only the remaining suffix runs through compute — chunked,
        `prefill_chunk_tokens` Q tokens per pass (vs one pass per suffix
        token with the knob at 0, the oracle path property tests compare
        against).  Cold prompts longer than the chunk are split the same way
        so the scheduler can interleave decodes between passes."""
        assert self.paged, "prefill_seq requires paged=True"
        plen = int(prompt.shape[0])
        self.seq_prompt_len[rid] = plen
        self.seq_len[rid] = plen
        token_ids = [int(t) for t in prompt]
        self.seq_hashes[rid] = BlockPool.chain_hashes(token_ids,
                                                      self.kv_block_size)
        for w in self.prompt_group:      # re-prefill after rollback-to-0
            if rid in w.pool.tables:
                w.free_paged_seq(rid)
        self.prefill_tokens_total += plen
        ck = self.prefill_chunk_tokens
        khashes = self._adoptable_prefix(token_ids)
        if self.tiered:
            candidates = (plen - 1) // self.kv_block_size
            telemetry.count("tier.prefix_hit_blocks", len(khashes))
            telemetry.count("tier.prefix_miss_blocks",
                            candidates - len(khashes))
        st = {"prompt": np.asarray(prompt, np.int32), "plen": plen,
              "start": 0, "pos": 0, "passes": 0, "x": None}
        if khashes:
            start = len(khashes) * self.kv_block_size
            for w in self.prompt_group:
                w.adopt_prefix(rid, khashes, start)
            self.prefix_hit_blocks += len(khashes)
            self.prefill_tokens_saved += start
            st["start"] = st["pos"] = start
            if ck > 0:
                st["mode"] = "chunk"
                for w in self.prompt_group:
                    w.ensure_prefill_table(rid, plen)
            else:
                st["mode"] = "token"
        elif self._chunkable() and (plen > ck or self.fused_ok):
            # fused rounds force chunk mode even for short cold prompts so
            # every in-flight prefill can pack into the round's chunk-set pass
            st["mode"] = "chunk"
            for w in self.prompt_group:
                w.ensure_prefill_table(rid, plen, token_ids=token_ids)
        else:
            st["mode"] = "batch"
        self._pending_prefill[rid] = st

    def prefill_seq_step(self, rid: int) -> Optional[jnp.ndarray]:
        """Run ONE pipeline pass of a staged prefill: the whole prompt
        (batch mode), one `prefill_chunk_tokens` chunk attending over the
        pool-resident prefix, or one suffix token through the decode path.
        Returns the prefill logits once the prompt is fully processed (and
        the post-prefill block streaming/replication/swap have run), else
        None — the engine interleaves decode steps between calls."""
        st = self._pending_prefill[rid]
        plen, pos = st["plen"], st["pos"]
        with telemetry.span("pass", kind=f"prefill_{st['mode']}"), \
                tracing.span("pass", rid=rid, kind=f"prefill_{st['mode']}",
                             pos=pos, plen=plen):
            if st["mode"] == "batch":
                x = jnp.asarray(st["prompt"])[None]
                for w in self.prompt_group:
                    x, _ = w.prefill_paged(
                        rid, x, token_ids=[int(t) for t in st["prompt"]])
                n_q = plen
            elif st["mode"] == "chunk":
                c = min(self.prefill_chunk_tokens, plen - pos)
                x = jnp.asarray(st["prompt"][pos:pos + c])[None]
                for w in self.prompt_group:
                    x = w.prefill_chunk_paged(rid, x, pos)
                n_q = c
            else:                        # token-at-a-time oracle path
                x = jnp.asarray(st["prompt"][pos:pos + 1])
                for w in self.prompt_group:
                    x = w.decode_paged(rid, x, pos)
                n_q = 1
            st["x"] = x
            self._after_prefill_pass(rid, st, n_q)
        if st["pos"] < plen:
            return None
        return self._finish_prefill(rid)

    def _after_prefill_pass(self, rid: int, st: dict, n_q: int) -> None:
        """Per-pass bookkeeping shared by the per-sequence and fused chunk
        paths: advance the cursor, publish the prefix hashes of the blocks
        whose pages the cursor just completed (cold chunked prefills only —
        adopted suffixes and the batched path publish elsewhere, see
        `publish_prefix_hashes`), and charge the modeled pass time."""
        st["pos"] += n_q
        st["passes"] += 1
        if st["mode"] == "chunk" and st["start"] == 0:
            for w in self.prompt_group:
                w.publish_prefix_hashes(rid, self.seq_hashes[rid], st["pos"])
        t = cm.chunked_prefill_pass_time(
            self.cfg, n_q, st["pos"], self.cfg.num_layers, 8, self.hw)
        self.round_prefill_model_s += t
        telemetry.advance(t)

    def _finish_prefill(self, rid: int) -> jnp.ndarray:
        st = self._pending_prefill.pop(rid)
        plen, start = st["plen"], st["start"]
        self.prefill_passes[rid] = st["passes"]
        if start > 0:
            self.adoption_suffix_log.append((plen - start, st["passes"]))
            self._register_compute(plen - start, plen)
        if self.mode == "disaggregated":
            self._stream_prompt_blocks(rid, plen)
        if self.replication:
            self._replicate_paged(rid, step=0)
        if self.swapping:
            for w in self.token_group:
                w.paged_offload(rid)
        self._track_kv_peak()
        return st["x"]

    def prefill_pending(self, rid: int) -> bool:
        return rid in self._pending_prefill

    def prefill_mode(self, rid: int) -> Optional[str]:
        """'chunk' | 'batch' | 'token' for a staged prefill, else None —
        the engine packs only chunk-mode prefills into a fused pass."""
        st = self._pending_prefill.get(rid)
        return None if st is None else st["mode"]

    def abort_prefill(self, rid: int) -> None:
        """Drop an in-flight prefill (e.g. a worker died mid-chunk and took
        the partial tables with it); the engine re-begins from scratch."""
        self._pending_prefill.pop(rid, None)
        for w in self.prompt_group:
            if rid in w.pool.tables:
                w.free_paged_seq(rid)

    def _adoptable_prefix(self, token_ids: List[int]) -> List[int]:
        """Prefix-chain hashes (full blocks) every prompt-side stage can
        serve from cache.  Capped so at least one suffix token runs through
        compute (the prefill logits must come from somewhere)."""
        if not self.tiered or self.cfg.family not in ("dense", "moe") \
                or self.cfg.num_patches:
            return []
        bs = self.kv_block_size
        hashes = BlockPool.chain_hashes(token_ids, bs)
        hashes = hashes[:(len(token_ids) - 1) // bs]
        if not hashes:
            return []
        k = min(w.adoptable_prefix_len(hashes) for w in self.prompt_group)
        return hashes[:k]

    def _register_compute(self, n_tokens: int, ctx: int) -> None:
        """Report modeled compute time to the streamer so its overlap report
        can say how much tier write-behind was hidden behind it."""
        if not self.tiered or n_tokens <= 0:
            return
        wl = cm.WorkloadSpec(prompt_len=max(ctx, 1), new_tokens=1, microbatch=1)
        t = cm.stage_token_time(self.cfg, wl, self.cfg.num_layers, 8,
                                max(ctx, 1), self.hw)
        self.streamer.compute_span(t * n_tokens)

    def _stream_prompt_blocks(self, rid: int, plen: int) -> None:
        topo_p = PipelineTopo(len(self.prompt_group), self.cfg.num_layers, 1)
        topo_t = PipelineTopo(len(self.token_group), self.cfg.num_layers, 1)
        dst_stores = {i: w.cache.host for i, w in enumerate(self.token_group)}
        for si, w in enumerate(self.prompt_group):
            stream_out_blocks(w.live_blocks(rid), si, topo_p, topo_t,
                              dst_stores, self.net, seq=rid)
            w.free_paged_seq(rid)
        for di, w in enumerate(self.token_group):
            blocks = stream_in_blocks(w.cache.host, di, topo_t, topo_p,
                                      self.net, seq=rid)
            # re-share full prompt blocks already live in the token-side pool
            # (same cap as `can_admit`'s discount, which counts on this)
            w.install_blocks(rid, plen, blocks,
                             hashes=self.seq_hashes.get(rid, [])[
                                 :(plen - 1) // self.kv_block_size])

    def decode_seq(self, rid: int, token: jnp.ndarray, step: int) -> jnp.ndarray:
        """One decode step for one sequence through the token pipeline.
        Raises PoolExhausted BEFORE mutating any pool, so the engine can
        preempt a victim and retry."""
        pos = self.seq_len[rid]
        with telemetry.span("pass", kind="perseq_decode"), \
                tracing.span("pass", rid=rid, seq=step, kind="perseq_decode"):
            if self.swapping:
                for w in self.token_group:
                    w.paged_restore(rid)
            for w in self.token_group:
                if w.pool.append_needs_block(rid) and w.pool.num_free() == 0:
                    raise PoolExhausted(
                        f"worker {w.wid} pool full (seq {rid})")
            x = token
            for w in self.token_group:
                x = w.decode_paged(rid, x, pos)
            self.seq_len[rid] = pos + 1
            self._register_compute(1, pos + 1)
            telemetry.advance(cm.stage_token_time(
                self.cfg, cm.WorkloadSpec(prompt_len=max(pos, 1),
                                          new_tokens=1, microbatch=1),
                self.cfg.num_layers, 8, pos + 1, self.hw))
        if self.replication:
            self._replicate_paged(rid, step=step, pos=pos)
        if self.swapping:
            for w in self.token_group:
                w.paged_offload(rid)
        for w in set(self.prompt_group + self.token_group):
            w.heartbeat()
        self._track_kv_peak()
        return x

    def decode_batch(self, rids: List[int], tokens,
                     steps: List[int]) -> jnp.ndarray:
        """ONE pipeline pass that decodes EVERY sequence in `rids` one step
        (fused rounds) — ragged per-sequence lengths over per-sequence block
        tables, vs `decode_seq`'s one pass per sequence.

        Per-sequence semantics are preserved exactly: capacity is pre-flighted
        across the WHOLE batch so PoolExhausted raises before any pool
        mutates (the engine preempts a victim and retries); replication still
        pushes each sequence's touched block with its own step; swap restores
        / offloads every sequence around the pass; and a worker death
        mid-pass surfaces as RuntimeError for the engine's detect-and-recover,
        which rolls every sequence back exactly like the per-sequence path.

        tokens: [B] int32 (each sequence's last sampled token); steps:
        per-sequence 1-based decode step.  Returns logits [B,V]."""
        poses = [self.seq_len[rid] for rid in rids]
        with telemetry.span("pass", kind="fused_decode"), \
                tracing.span("pass", kind="fused_decode", rids=list(rids)):
            if self.swapping:
                for w in self.token_group:
                    for rid in rids:
                        w.paged_restore(rid)
            for w in self.token_group:
                need = sum(1 for rid in rids if w.pool.append_needs_block(rid))
                if need > w.pool.num_free():
                    raise PoolExhausted(
                        f"worker {w.wid} pool cannot absorb a fused round of "
                        f"{len(rids)} appends ({need} needed, "
                        f"{w.pool.num_free()} free)")
            x = jnp.asarray(np.asarray(tokens, np.int32))
            for w in self.token_group:
                x = w.decode_paged_batch(rids, x, poses)
            for rid, pos in zip(rids, poses):
                self.seq_len[rid] = pos + 1
                self._register_compute(1, pos + 1)
            ctx = max(1, (sum(poses) + len(poses)) // max(len(poses), 1))
            telemetry.advance(cm.decode_round_time(
                self.cfg, len(rids), ctx, self.cfg.num_layers, 8, self.hw,
                fused=True))
        if self.replication:
            for rid, step, pos in zip(rids, steps, poses):
                self._replicate_paged(rid, step=step, pos=pos)
        if self.swapping:
            for w in self.token_group:
                for rid in rids:
                    w.paged_offload(rid)
        for w in set(self.prompt_group + self.token_group):
            w.heartbeat()
        self._track_kv_peak()
        return x

    def prefill_chunkset_pass(self, rids: List[int]
                              ) -> Dict[int, Optional[jnp.ndarray]]:
        """Advance the staged chunk-mode prefills of ALL `rids` by one chunk
        each in ONE pipeline pass through the prompt group — the fused
        analogue of calling `prefill_seq_step` once per sequence.  Ragged
        chunk lengths (a prompt's final chunk may be short) are padded to the
        set's longest and masked inside the pass.  Returns {rid:
        prefill_logits | None}; a completed prompt runs the same post-prefill
        streaming / replication / swap as the per-sequence path."""
        with telemetry.span("pass", kind="chunkset"), \
                tracing.span("pass", kind="chunkset", rids=list(rids)):
            return self._prefill_chunkset_pass(rids)

    def _prefill_chunkset_pass(self, rids: List[int]
                               ) -> Dict[int, Optional[jnp.ndarray]]:
        sts = [self._pending_prefill[r] for r in rids]
        assert all(st["mode"] == "chunk" for st in sts), \
            "prefill_chunkset_pass packs chunk-mode prefills only"
        ck = self.prefill_chunk_tokens
        cs = [min(ck, st["plen"] - st["pos"]) for st in sts]
        cmax = max(cs)
        toks = np.zeros((len(rids), cmax), np.int32)
        for i, st in enumerate(sts):
            toks[i, :cs[i]] = st["prompt"][st["pos"]:st["pos"] + cs[i]]
        pos0s = [st["pos"] for st in sts]
        x = jnp.asarray(toks)
        for w in self.prompt_group:
            x = w.prefill_chunk_paged_batch(rids, x, pos0s, cs)
        out: Dict[int, Optional[jnp.ndarray]] = {}
        for i, (rid, st) in enumerate(zip(rids, sts)):
            self._after_prefill_pass(rid, st, cs[i])
            if st["pos"] < st["plen"]:
                out[rid] = None
            else:
                st["x"] = x[i:i + 1]
                out[rid] = self._finish_prefill(rid)
        return out

    def _replicate_paged(self, rid: int, step: int,
                         pos: Optional[int] = None) -> None:
        """Ring-replicate at BLOCK granularity: prefill pushes every live
        block, a decode step pushes only the block it touched."""
        group = self.token_group
        n = len(group)
        for i, w in enumerate(group):
            if rid not in w.pool.tables:
                continue
            peer = group[(i + 1) % n]
            if pos is None:
                for j, arrays in w.live_blocks(rid).items():
                    w.cache.replicate_block_to(peer.cache, rid, j, arrays,
                                               step, self.controller.ack_replication)
            else:
                j, arrays = w.touched_block(rid, pos)
                w.cache.replicate_block_to(peer.cache, rid, j, arrays, step,
                                           self.controller.ack_replication)
        self.streamer.drain()

    def preempt_seq(self, rid: int) -> None:
        """Swap a running sequence fully out (block-granular) to free pool
        space for another request; `resume_seq` brings it back.  Offload is
        a no-op on workers where the sequence is already swapped out."""
        for w in self.token_group:
            w.paged_offload(rid)

    def resident_blocks(self, rid: int) -> int:
        """Device-resident blocks a preemption of `rid` would free."""
        return sum(len(w.pool.tables.get(rid, ())) for w in self.token_group)

    def can_resume(self, rid: int, n_active: int) -> bool:
        need = blocks_for(self.seq_len[rid] + 1, self.kv_block_size) + n_active
        return all(w.pool.num_free() >= need for w in self.token_group)

    def resume_seq(self, rid: int) -> None:
        for w in self.token_group:
            w.paged_restore(rid)

    def free_seq(self, rid: int) -> None:
        """Retire a finished sequence: blocks return to the pool immediately
        (this is what lets the engine admit queued work every step)."""
        for w in set(self.prompt_group + self.token_group):
            w.free_paged_seq(rid)
            for key in [k for k in w.cache.replica.keys()
                        if f"/seq{rid}/" in k]:
                w.cache.replica.delete(key)
        self.seq_len.pop(rid, None)
        self.seq_prompt_len.pop(rid, None)
        self.seq_hashes.pop(rid, None)
        self._pending_prefill.pop(rid, None)

    def pool_stats(self) -> Dict[str, int]:
        used = max((w.pool.num_used() for w in self.token_group), default=0)
        peak = max((w.pool.peak_used_blocks for w in self.token_group), default=0)
        return {"used_blocks": used, "peak_blocks": peak,
                "peak_kv_bytes": self.kv_bytes_peak}

    def tier_stats(self) -> Dict[str, float]:
        """Aggregate the per-stage tier-manager counters plus the cluster's
        prefix-reuse tallies (empty unless tiered=True)."""
        agg: Dict[str, float] = {}
        for w in set(self.prompt_group + self.token_group):
            if getattr(w, "tier", None) is None:
                continue
            for k, v in w.tier.stats().items():
                agg[k] = agg.get(k, 0) + v
        if agg or self.tiered:
            agg["prefill_tokens_total"] = self.prefill_tokens_total
            agg["prefill_tokens_saved"] = self.prefill_tokens_saved
            agg["prefix_hit_blocks"] = self.prefix_hit_blocks
        return agg

    def _replicate(self, mb: int, token_range, step: int,
                   group: List[StageWorker]) -> None:
        n = len(group)
        for i, w in enumerate(group):
            if mb not in w.kv and not self.swapping:
                continue
            kv = w.kv.get(mb)
            if kv is None:      # swapped out: replicate from host copy
                kv = {leaf: jnp.asarray(w.cache.host.get(f"swap/mb{mb}/{leaf}"))
                      for leaf in ("k", "v")}
            peer = group[(i + 1) % n]
            w.cache.replicate_to(peer.cache, mb, kv, token_range, step,
                                 self.controller.ack_replication)
        self.streamer.drain()

    # ------------------------------------------------------------------
    # failure handling (paper §4.2.3) + straggler migration
    # ------------------------------------------------------------------
    def inject_failure(self, wid: int) -> None:
        # observability point only — lets a recorded trace (and fault_trace
        # assertions) show every delivered kill, whatever path requested it
        faults.fire("cluster.fail", tag=f"w{wid}")
        tracing.event("cluster.kill", wid=wid)
        t = telemetry.current()
        if t is not None:
            # mark the modeled clock; the engine closes the mark into a
            # `cluster.recovery_s` observation at the first post-restore token
            self._recovery_marks.append(t.clock_s)
            t.count("cluster.failures", 1)
        for w in set(self.prompt_group + self.token_group):
            if w.wid == wid:
                w.kill()
                self.controller.log_event("failure", wid=wid)
                return
        raise KeyError(wid)

    def take_recovery_marks(self) -> List[float]:
        """Drain the pending failure clock marks (see `inject_failure`)."""
        marks, self._recovery_marks = self._recovery_marks, []
        return marks

    def detect_and_recover(self, active_mbs: List[int]) -> Dict[int, int]:
        """Controller-driven recovery.  Returns {mb: resume_step} (empty if
        no failure)."""
        dead = self.controller.check_failures()
        resume: Dict[int, int] = {}
        for wid in dead:
            with tracing.span("recovery", wid=wid):
                resume.update(self._recover_worker(wid, active_mbs))
        return resume

    def _recover_worker(self, wid: int, active_mbs: List[int]) -> Dict[int, int]:
        if (self.mode == "disaggregated"
                and any(w.wid == wid for w in self.prompt_group)):
            # prompt workers hold no cross-microbatch state: rebuild in place
            idx = next(i for i, w in enumerate(self.prompt_group) if w.wid == wid)
            ranges = _stage_ranges(self.cfg.num_layers, len(self.prompt_group))
            lo, hi = ranges[idx]
            old = self.prompt_group[idx]
            neww = StageWorker(wid, self.model, self.params, lo, hi,
                               first=old.first, last=old.last, role=old.role,
                               hw=self.hw, streamer=self.streamer)
            if self.paged:
                neww.enable_paging(self.kv_pool_blocks, self.kv_block_size)
                if self.tiered:
                    neww.enable_tiering(self.tier_cfg)
            self.prompt_group[idx] = neww
            self.controller.workers = [neww if w.wid == wid else w
                                       for w in self.controller.workers]
            self.controller.log_event("recovery", wid=wid, resume={})
            return {}
        group = self.token_group
        idx = next(i for i, w in enumerate(group) if w.wid == wid)
        n = len(group)
        old = group[idx]
        ranges = _stage_ranges(self.cfg.num_layers, n)
        lo, hi = ranges[idx]
        # fresh worker: weights re-sliced from the checkpointed full params
        neww = StageWorker(wid, self.model, self.params, lo, hi,
                           first=old.first, last=old.last, role=old.role,
                           hw=self.hw, streamer=self.streamer,
                           compress_replicas=self.compress_replicas)
        group[idx] = neww
        self.controller.workers = [neww if w.wid == wid else w
                                   for w in self.controller.workers]
        succ = group[(idx + 1) % n]
        pred = group[(idx - 1) % n]
        if self.paged:
            return self._recover_worker_paged(wid, old, neww, succ, pred,
                                              active_mbs)
        # step 1: successor returns the failed worker's replica
        for mb in active_mbs:
            arrays = {}
            for leaf in ("k", "v"):
                key = f"w{wid}/mb{mb}/{leaf}"
                if key in succ.cache.replica:
                    arrays[leaf] = succ.cache.replica.get(key)
            if arrays:
                neww.install_kv(mb, arrays)
                if self.swapping:   # rebuild host copy too
                    neww.cache.swap_out(mb, neww.kv[mb])
        # step 2: predecessor re-replicates its own KV to the new worker
        for mb in active_mbs:
            kv = pred.kv.get(mb)
            if kv is None and pred.cache.host_has(mb):
                kv = {leaf: jnp.asarray(pred.cache.host.get(f"swap/mb{mb}/{leaf}"))
                      for leaf in ("k", "v")}
            if kv is not None:
                pred.cache.replicate_to(neww.cache, mb, kv,
                                        (0, self.mb_pos[mb]),
                                        self.controller.replicated_step(pred.wid, mb),
                                        self.controller.ack_replication)
        self.streamer.drain()
        # step 3: resume point per microbatch
        resume = self.controller.resume_point(wid, active_mbs)
        # roll back cache positions; step i writes at prompt_len + i - 1
        for mb, r in resume.items():
            self.mb_pos[mb] = self.mb_prompt_len[mb] + max(r - 1, 0)
        self.controller.log_event("recovery", wid=wid, resume=dict(resume))
        return resume

    def _recover_worker_paged(self, wid: int, old: StageWorker,
                              neww: StageWorker,
                              succ: StageWorker, pred: StageWorker,
                              active: List[int]) -> Dict[int, int]:
        """Paged 4-step recovery: only LIVE blocks move.  Each sequence is
        restored from the LOWEST tier holding a replica: the dead worker's
        persistent SSD tier first (it survives the machine), else the
        successor's replica-ring blocks; the predecessor then re-streams its
        own blocks, and every sequence rolls back to its last fully
        replicated step."""
        neww.enable_paging(self.kv_pool_blocks, self.kv_block_size)
        if self.tiered:
            # the dead machine's disk outlives it: point the fresh worker's
            # tier manager at the same root and rebuild the index from the
            # self-describing keys (prefix cache + spilled swap blocks)
            root = old.tier.ssd.root if old.tier is not None else None
            neww.enable_tiering(dataclasses.replace(self.tier_cfg,
                                                    ssd_root=root))
            self.streamer.drain()         # pending write-behinds land first
            neww.tier.reattach()
        bs = self.kv_block_size
        # step 1: restore each sequence from the lowest tier holding it —
        # the reattached SSD tier, else the successor's replica blocks
        for rid in active:
            rep = self.controller.replicated_step(wid, rid)
            if rep < 0:
                continue            # nothing replicated: engine re-prefills
            avail = self.seq_prompt_len[rid] + max(rep, 0)
            keep = blocks_for(avail, bs)
            blocks = None
            # the SSD copy is only authoritative if the sequence really was
            # swapped out at (at least) the resume length — the peers'
            # symmetric swap state is the witness for the dead worker's
            if self.tiered and neww.tier is not None and \
                    pred.paged_swapped.get(rid, -1) >= avail:
                blocks = neww.tier.restore_swap_from_ssd(rid, keep)
            if blocks is None:
                blocks = {j: a
                          for j, a in succ.cache.replica_blocks(wid, rid).items()
                          if j < keep}
            # re-share fully-restored prompt blocks with co-resident
            # sequences — a pool that only fit its load through prefix
            # sharing must recover through prefix sharing too
            neww.install_blocks(rid, avail, blocks,
                                hashes=self.seq_hashes.get(rid, [])[:avail // bs])
            # a swapped/preempted sequence goes back to host on the fresh
            # worker too, so recovery leaves residency exactly as it found it
            if self.swapping or rid in pred.paged_swapped:
                neww.paged_offload(rid)
        # step 2: predecessor re-replicates its own live blocks; a swapped or
        # preempted sequence is brought back for the send, then re-offloaded
        # so pool occupancy is unchanged by recovery
        for rid in active:
            was_swapped = rid in pred.paged_swapped
            pred.paged_restore(rid)
            if rid not in pred.pool.tables:
                continue
            step = self.controller.replicated_step(pred.wid, rid)
            for j, arrays in pred.live_blocks(rid).items():
                pred.cache.replicate_block_to(neww.cache, rid, j, arrays, step,
                                              self.controller.ack_replication)
            if was_swapped:
                pred.paged_offload(rid)
        self.streamer.drain()
        # steps 3+4: resume point per sequence; roll every pool back to it
        resume = self.controller.resume_point(wid, active)
        for rid, r in resume.items():
            new_len = self.seq_prompt_len[rid] + max(r - 1, 0) if r > 0 else 0
            self.seq_len[rid] = new_len
            for w in self.token_group:
                if rid in w.pool.tables:
                    if new_len > 0:
                        w.pool.truncate(rid, new_len)
                    else:
                        w.free_paged_seq(rid)
                if rid in w.paged_swapped:
                    w.paged_swapped[rid] = min(w.paged_swapped[rid], new_len)
        self.controller.log_event("recovery", wid=wid, resume=dict(resume))
        return resume

    def migrate_worker(self, wid: int, active_mbs: List[int]) -> Dict[int, int]:
        """Straggler mitigation: proactively move a slow stage to a fresh
        worker using the replication ring (beyond-paper, same machinery)."""
        self.controller.log_event("migrate", wid=wid)
        self.inject_failure(wid)
        return self.detect_and_recover(active_mbs)

    # ------------------------------------------------------------------
    # elastic repartitioning (beyond-paper)
    # ------------------------------------------------------------------
    def repartition(self, new_depth: int, active_mbs: List[int]) -> None:
        """Re-split the token pipeline to `new_depth` stages, migrating all
        live KV through DéjàVuLib stream_out/stream_in."""
        old_group = self.token_group
        bsz = max(self.mb_batch.values()) if self.mb_batch else 1
        topo_old = PipelineTopo(len(old_group), self.cfg.num_layers, bsz)
        topo_new = PipelineTopo(new_depth, self.cfg.num_layers, bsz)
        ranges = _stage_ranges(self.cfg.num_layers, new_depth)
        wid0 = max(w.wid for w in set(self.prompt_group + self.token_group)) + 1
        new_group = []
        for i, (lo, hi) in enumerate(ranges):
            new_group.append(StageWorker(
                wid0 + i, self.model, self.params, lo, hi, first=(i == 0),
                last=(i == len(ranges) - 1),
                role=old_group[0].role, hw=self.hw, streamer=self.streamer))
        if self.paged:
            for w in new_group:
                w.enable_paging(self.kv_pool_blocks, self.kv_block_size)
                if self.tiered:
                    # fresh (cold) tiers: the per-stage layer slicing changed,
                    # so the old stages' cached blocks no longer match
                    w.enable_tiering(self.tier_cfg)
            dst_stores = {i: w.cache.host for i, w in enumerate(new_group)}
            for rid in active_mbs:
                for si, w in enumerate(old_group):
                    if self.swapping:
                        w.paged_restore(rid)
                    stream_out_blocks(w.live_blocks(rid), si, topo_old,
                                      topo_new, dst_stores, self.net, seq=rid)
                for di, w in enumerate(new_group):
                    blocks = stream_in_blocks(w.cache.host, di, topo_new,
                                              topo_old, self.net, seq=rid)
                    w.install_blocks(rid, self.seq_len[rid], blocks)
            self.token_group = new_group
            if self.mode == "colocated":
                self.prompt_group = new_group
            for w in new_group:
                self.controller.register(w)
            self.controller.log_event("repartition", depth=new_depth)
            return
        dst_stores = {i: w.cache.host for i, w in enumerate(new_group)}
        for mb in active_mbs:
            cur = self.mb_pos[mb]
            for si, w in enumerate(old_group):
                if self.swapping:
                    w.restore(mb)
                kv = w.kv.get(mb)
                state = {"kv": {k: np.asarray(v) for k, v in kv.items()}}
                stream_out(state, si, topo_old, topo_new, dst_stores, self.net,
                           mb=f"{mb}", token_range=(0, cur))
            for di, w in enumerate(new_group):
                lo, hi = topo_new.layer_range(di)
                hkv, dh = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
                b = np.asarray(old_group[0].kv[list(old_group[0].kv)[0]]["k"]).shape[1] \
                    if old_group[0].kv else None
                shapes = {"kv": {"k": ((hi - lo, b, self.mb_max_len[mb], hkv, dh), self.cfg.dtype),
                                 "v": ((hi - lo, b, self.mb_max_len[mb], hkv, dh), self.cfg.dtype)}}
                local = stream_in(w.cache.host, di, topo_new, topo_old, shapes,
                                  self.net, mb=f"{mb}", token_range=(0, cur))
                w.install_kv(mb, local["kv"])
        self.token_group = new_group
        if self.mode == "colocated":
            self.prompt_group = new_group
        for w in new_group:
            self.controller.register(w)
        self.controller.log_event("repartition", depth=new_depth)
