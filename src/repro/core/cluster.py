"""In-process DéjàVu cluster: real pipeline-parallel serving with prompt/token
disaggregation, microbatch swapping, ring replication, failure recovery,
straggler migration, and elastic repartitioning.

Workers are real objects holding real arrays; every byte between them moves
through DéjàVuLib primitives over modeled transports, so tests assert on
actual tokens while benchmarks read the modeled transfer timelines.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.controller import Controller
from repro.core.dejavulib import (PipelineTopo, StreamEngine, NetworkTransport,
                                  stream_in, stream_out)
from repro.core.dejavulib.transport import HardwareModel, DEFAULT_HW
from repro.core.worker import StageWorker


def _stage_ranges(num_layers: int, depth: int) -> List[Tuple[int, int]]:
    assert depth <= num_layers, f"pipeline depth {depth} > {num_layers} layers"
    splits = np.array_split(np.arange(num_layers), depth)
    return [(int(s[0]), int(s[-1]) + 1) for s in splits]


class DejaVuCluster:
    def __init__(self, cfg: ArchConfig, model, params, n_workers: int, *,
                 mode: str = "colocated", dp_split: Optional[Tuple[int, int]] = None,
                 swapping: bool = False, replication: bool = False,
                 compress_replicas: bool = False,
                 max_resident: int = 2, hw: HardwareModel = DEFAULT_HW):
        assert mode in ("colocated", "disaggregated")
        if mode == "disaggregated":
            assert dp_split is not None and sum(dp_split) == n_workers
        self.cfg = cfg
        self.model = model
        self.params = params             # full weights (the checkpoint store)
        self.mode = mode
        self.swapping = swapping
        self.replication = replication
        self.compress_replicas = compress_replicas
        self.max_resident = max_resident
        self.hw = hw
        self.streamer = StreamEngine("cluster")
        self.controller = Controller()
        self.net = NetworkTransport(hw)

        if mode == "colocated":
            self.prompt_group = self.token_group = self._build_group(
                n_workers, role="both", wid0=0)
        else:
            dp, dt = dp_split
            self.prompt_group = self._build_group(dp, role="prompt", wid0=0)
            self.token_group = self._build_group(dt, role="token", wid0=dp)
        for w in set(self.prompt_group + self.token_group):
            self.controller.register(w)
        self.mb_pos: Dict[int, int] = {}        # current KV length per microbatch
        self.mb_prompt_len: Dict[int, int] = {}
        self.mb_max_len: Dict[int, int] = {}
        self.mb_batch: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def _build_group(self, depth: int, role: str, wid0: int) -> List[StageWorker]:
        ranges = _stage_ranges(self.cfg.num_layers, depth)
        ws = []
        for i, (lo, hi) in enumerate(ranges):
            ws.append(StageWorker(wid0 + i, self.model, self.params, lo, hi,
                                  first=(i == 0), last=(i == len(ranges) - 1),
                                  role=role, hw=self.hw, streamer=self.streamer,
                                  compress_replicas=self.compress_replicas))
        return ws

    def _topo(self, group: List[StageWorker]) -> PipelineTopo:
        return PipelineTopo(depth=len(group), num_layers=self.cfg.num_layers,
                            microbatch=0)

    # ------------------------------------------------------------------
    # serving primitives
    # ------------------------------------------------------------------
    def prefill_mb(self, mb: int, tokens: jnp.ndarray, max_new: int) -> jnp.ndarray:
        """Prefill a microbatch through the prompt pipeline; in disaggregated
        mode, stream its prompt KV to the token pipeline (paper §4.2.1)."""
        b, plen = tokens.shape
        # cache length aligned to the kv_pack DMA token block (8)
        max_len = -(-(plen + max_new) // 8) * 8
        self.mb_batch[mb] = b
        self.mb_pos[mb] = plen
        self.mb_prompt_len[mb] = plen
        self.mb_max_len[mb] = max_len
        x = tokens
        for w in self.prompt_group:
            x = w.prefill(mb, x, max_len)
        logits = x
        if self.mode == "disaggregated":
            self._stream_prompt_kv(mb, plen)
        if self.replication:
            self._replicate(mb, (0, plen), step=0, group=self.token_group)
        if self.swapping:
            for w in self.token_group:
                if mb in w.kv:
                    w.offload(mb)           # full first offload to host
        return logits

    def _stream_prompt_kv(self, mb: int, plen: int) -> None:
        bsz = self.mb_batch[mb]
        topo_p = PipelineTopo(len(self.prompt_group), self.cfg.num_layers, bsz)
        topo_t = PipelineTopo(len(self.token_group), self.cfg.num_layers, bsz)
        dst_stores = {i: w.cache.host for i, w in enumerate(self.token_group)}
        for si, w in enumerate(self.prompt_group):
            kv = w.kv.pop(mb)
            state = {"kv": {k: np.asarray(v) for k, v in kv.items()}}
            mbk = f"{mb}"
            stream_out(state, si, topo_p, topo_t, dst_stores, self.net,
                       mb=mbk, token_range=(0, plen))
        # token side: merge chunks into local caches sized max_len
        b = None
        for di, w in enumerate(self.token_group):
            lo, hi = topo_t.layer_range(di)
            hkv, dh = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
            # batch size from any incoming chunk
            some_key = next(k for k in w.cache.host.keys() if k.startswith(f"mb{mb}/kv/"))
            b = w.cache.host.get(some_key).shape[1]
            shapes = {"kv": {"k": ((hi - lo, b, self.mb_max_len[mb], hkv, dh), self.cfg.dtype),
                             "v": ((hi - lo, b, self.mb_max_len[mb], hkv, dh), self.cfg.dtype)}}
            local = stream_in(w.cache.host, di, topo_t, topo_p, shapes, self.net,
                              mb=f"{mb}", token_range=(0, plen))
            w.install_kv(mb, local["kv"])
            for key in [k for k in w.cache.host.keys() if k.startswith(f"mb{mb}/")]:
                w.cache.host.delete(key)

    def decode_mb(self, mb: int, token: jnp.ndarray, step: int) -> jnp.ndarray:
        """One decode step through the token pipeline.  Returns logits [B,V].
        `step` is 1-based (step i consumes token_{i-1})."""
        pos = self.mb_pos[mb]
        if self.swapping:
            for w in self.token_group:
                w.restore(mb)
        x = token
        for w in self.token_group:
            x = w.decode(mb, x, pos)
        self.mb_pos[mb] = pos + 1
        if self.replication:
            self._replicate(mb, (pos, pos + 1), step=step, group=self.token_group)
        if self.swapping:
            for w in self.token_group:
                w.offload(mb, token_range=(pos, pos + 1))
        for w in set(self.prompt_group + self.token_group):
            w.heartbeat()
        return x

    def _replicate(self, mb: int, token_range, step: int,
                   group: List[StageWorker]) -> None:
        n = len(group)
        for i, w in enumerate(group):
            if mb not in w.kv and not self.swapping:
                continue
            kv = w.kv.get(mb)
            if kv is None:      # swapped out: replicate from host copy
                kv = {leaf: jnp.asarray(w.cache.host.get(f"swap/mb{mb}/{leaf}"))
                      for leaf in ("k", "v")}
            peer = group[(i + 1) % n]
            w.cache.replicate_to(peer.cache, mb, kv, token_range, step,
                                 self.controller.ack_replication)
        self.streamer.drain()

    # ------------------------------------------------------------------
    # failure handling (paper §4.2.3) + straggler migration
    # ------------------------------------------------------------------
    def inject_failure(self, wid: int) -> None:
        for w in set(self.prompt_group + self.token_group):
            if w.wid == wid:
                w.kill()
                self.controller.log_event("failure", wid=wid)
                return
        raise KeyError(wid)

    def detect_and_recover(self, active_mbs: List[int]) -> Dict[int, int]:
        """Controller-driven recovery.  Returns {mb: resume_step} (empty if
        no failure)."""
        dead = self.controller.check_failures()
        resume: Dict[int, int] = {}
        for wid in dead:
            resume.update(self._recover_worker(wid, active_mbs))
        return resume

    def _recover_worker(self, wid: int, active_mbs: List[int]) -> Dict[int, int]:
        if (self.mode == "disaggregated"
                and any(w.wid == wid for w in self.prompt_group)):
            # prompt workers hold no cross-microbatch state: rebuild in place
            idx = next(i for i, w in enumerate(self.prompt_group) if w.wid == wid)
            ranges = _stage_ranges(self.cfg.num_layers, len(self.prompt_group))
            lo, hi = ranges[idx]
            old = self.prompt_group[idx]
            neww = StageWorker(wid, self.model, self.params, lo, hi,
                               first=old.first, last=old.last, role=old.role,
                               hw=self.hw, streamer=self.streamer)
            self.prompt_group[idx] = neww
            self.controller.workers = [neww if w.wid == wid else w
                                       for w in self.controller.workers]
            self.controller.log_event("recovery", wid=wid, resume={})
            return {}
        group = self.token_group
        idx = next(i for i, w in enumerate(group) if w.wid == wid)
        n = len(group)
        old = group[idx]
        ranges = _stage_ranges(self.cfg.num_layers, n)
        lo, hi = ranges[idx]
        # fresh worker: weights re-sliced from the checkpointed full params
        neww = StageWorker(wid, self.model, self.params, lo, hi,
                           first=old.first, last=old.last, role=old.role,
                           hw=self.hw, streamer=self.streamer,
                           compress_replicas=self.compress_replicas)
        group[idx] = neww
        self.controller.workers = [neww if w.wid == wid else w
                                   for w in self.controller.workers]
        succ = group[(idx + 1) % n]
        pred = group[(idx - 1) % n]
        # step 1: successor returns the failed worker's replica
        for mb in active_mbs:
            arrays = {}
            for leaf in ("k", "v"):
                key = f"w{wid}/mb{mb}/{leaf}"
                if key in succ.cache.replica:
                    arrays[leaf] = succ.cache.replica.get(key)
            if arrays:
                neww.install_kv(mb, arrays)
                if self.swapping:   # rebuild host copy too
                    neww.cache.swap_out(mb, neww.kv[mb])
        # step 2: predecessor re-replicates its own KV to the new worker
        for mb in active_mbs:
            kv = pred.kv.get(mb)
            if kv is None and pred.cache.host_has(mb):
                kv = {leaf: jnp.asarray(pred.cache.host.get(f"swap/mb{mb}/{leaf}"))
                      for leaf in ("k", "v")}
            if kv is not None:
                pred.cache.replicate_to(neww.cache, mb, kv,
                                        (0, self.mb_pos[mb]),
                                        self.controller.replicated_step(pred.wid, mb),
                                        self.controller.ack_replication)
        self.streamer.drain()
        # step 3: resume point per microbatch
        resume = self.controller.resume_point(wid, active_mbs)
        # roll back cache positions; step i writes at prompt_len + i - 1
        for mb, r in resume.items():
            self.mb_pos[mb] = self.mb_prompt_len[mb] + max(r - 1, 0)
        self.controller.log_event("recovery", wid=wid, resume=dict(resume))
        return resume

    def migrate_worker(self, wid: int, active_mbs: List[int]) -> Dict[int, int]:
        """Straggler mitigation: proactively move a slow stage to a fresh
        worker using the replication ring (beyond-paper, same machinery)."""
        self.controller.log_event("migrate", wid=wid)
        self.inject_failure(wid)
        return self.detect_and_recover(active_mbs)

    # ------------------------------------------------------------------
    # elastic repartitioning (beyond-paper)
    # ------------------------------------------------------------------
    def repartition(self, new_depth: int, active_mbs: List[int]) -> None:
        """Re-split the token pipeline to `new_depth` stages, migrating all
        live KV through DéjàVuLib stream_out/stream_in."""
        old_group = self.token_group
        bsz = max(self.mb_batch.values()) if self.mb_batch else 1
        topo_old = PipelineTopo(len(old_group), self.cfg.num_layers, bsz)
        topo_new = PipelineTopo(new_depth, self.cfg.num_layers, bsz)
        ranges = _stage_ranges(self.cfg.num_layers, new_depth)
        wid0 = max(w.wid for w in set(self.prompt_group + self.token_group)) + 1
        new_group = []
        for i, (lo, hi) in enumerate(ranges):
            new_group.append(StageWorker(
                wid0 + i, self.model, self.params, lo, hi, first=(i == 0),
                last=(i == len(ranges) - 1),
                role=old_group[0].role, hw=self.hw, streamer=self.streamer))
        dst_stores = {i: w.cache.host for i, w in enumerate(new_group)}
        for mb in active_mbs:
            cur = self.mb_pos[mb]
            for si, w in enumerate(old_group):
                if self.swapping:
                    w.restore(mb)
                kv = w.kv.get(mb)
                state = {"kv": {k: np.asarray(v) for k, v in kv.items()}}
                stream_out(state, si, topo_old, topo_new, dst_stores, self.net,
                           mb=f"{mb}", token_range=(0, cur))
            for di, w in enumerate(new_group):
                lo, hi = topo_new.layer_range(di)
                hkv, dh = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
                b = np.asarray(old_group[0].kv[list(old_group[0].kv)[0]]["k"]).shape[1] \
                    if old_group[0].kv else None
                shapes = {"kv": {"k": ((hi - lo, b, self.mb_max_len[mb], hkv, dh), self.cfg.dtype),
                                 "v": ((hi - lo, b, self.mb_max_len[mb], hkv, dh), self.cfg.dtype)}}
                local = stream_in(w.cache.host, di, topo_new, topo_old, shapes,
                                  self.net, mb=f"{mb}", token_range=(0, cur))
                w.install_kv(mb, local["kv"])
        self.token_group = new_group
        if self.mode == "colocated":
            self.prompt_group = new_group
        for w in new_group:
            self.controller.register(w)
        self.controller.log_event("repartition", depth=new_depth)
