"""Discrete-event pipeline scheduler.

Models pipeline-parallel LLM serving exactly as the paper draws it (Figs. 3,
8, 26): work items (P = prompt step, T = one token step) flow through stages
with three dependency kinds —

  activation:  (mb, step, stage s) needs (mb, step, s−1)
  cache order: (mb, T_i, stage s) needs (mb, T_{i−1}, s)
  admission:   at most `max_inflight` microbatches in flight; the next
               queued microbatch enters when a finishing one clears stage 0

Stage occupancy is greedy-FIFO.  The same engine drives the Appendix-B
simulator (durations only) and, via `exec_cb`, the real in-process cluster
(items executed in dependency order with actual arrays).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

Key = Tuple[str, int, str, int, int]  # (pipeline, mb, kind, step, stage)


@dataclass(frozen=True)
class Item:
    pipeline: str
    mb: int
    kind: str          # "P" | "T"
    step: int          # 0 for P, token index for T
    stage: int
    duration: float

    @property
    def key(self) -> Key:
        return (self.pipeline, self.mb, self.kind, self.step, self.stage)


@dataclass
class Trace:
    start: Dict[Key, float] = field(default_factory=dict)
    finish: Dict[Key, float] = field(default_factory=dict)

    @property
    def makespan(self) -> float:
        return max(self.finish.values(), default=0.0)


class EventEngine:
    """Generic dependency-driven greedy scheduler."""

    def __init__(self, exec_cb: Optional[Callable[[Item], None]] = None):
        self.exec_cb = exec_cb
        self.items: Dict[Key, Item] = {}
        self.deps: Dict[Key, List[Key]] = {}
        self.extra_delay: Dict[Key, float] = {}
        self.release: Dict[Key, float] = {}
        self._uid = itertools.count()

    def add(self, item: Item, deps: List[Key] = (), release: float = 0.0,
            extra_delay: float = 0.0) -> None:
        self.items[item.key] = item
        self.deps[item.key] = list(deps)
        self.release[item.key] = release
        self.extra_delay[item.key] = extra_delay

    def run(self, stage_free: Optional[Dict[Tuple[str, int], float]] = None
            ) -> Trace:
        trace = Trace()
        stage_free = dict(stage_free or {})
        pending = {k: set(d for d in ds if d in self.items)
                   for k, ds in self.deps.items()}
        dependents: Dict[Key, List[Key]] = {}
        for k, ds in pending.items():
            for d in ds:
                dependents.setdefault(d, []).append(k)
        heap: List[Tuple[float, int, Key]] = []
        for k, ds in pending.items():
            if not ds:
                heapq.heappush(heap, (self.release[k], next(self._uid), k))
        done = set()
        while heap:
            ready, _, key = heapq.heappop(heap)
            if key in done:
                continue
            item = self.items[key]
            sk = (item.pipeline, item.stage)
            start = max(ready, stage_free.get(sk, 0.0))
            fin = start + item.duration + self.extra_delay[key]
            stage_free[sk] = fin
            trace.start[key] = start
            trace.finish[key] = fin
            done.add(key)
            if self.exec_cb is not None:
                self.exec_cb(item)
            for dep in dependents.get(key, ()):  # release newly-ready items
                pending[dep].discard(key)
                if not pending[dep]:
                    rel = max([self.release[dep]] +
                              [trace.finish[d] for d in self.deps[dep]
                               if d in trace.finish])
                    heapq.heappush(heap, (rel, next(self._uid), dep))
        return trace


@dataclass
class Job:
    mb: int
    arrival: float
    n_tokens: int


# ---------------------------------------------------------------------------
# Strict round-robin pipeline schedule (FasterTransformer semantics, Fig. 3)
# ---------------------------------------------------------------------------

def rr_schedule(jobs: List[Job], *, pipeline: str, depth: int, p_dur: float,
                t_dur: float, max_inflight: Optional[int] = None,
                do_prompt: bool = True, do_tokens: bool = True,
                token_gate: Optional[Dict[int, float]] = None,
                exec_cb: Optional[Callable[[Item], None]] = None
                ) -> Tuple[Trace, List[Item]]:
    """Generate + time the strict round-robin schedule the paper's systems use
    (FasterTransformer, modified for microbatch-level replacement — §5).

    Each stage processes in-flight microbatch slots in a FIXED cyclic order
    (P on entry, then T steps); a slot is backfilled from the queue when its
    microbatch early-stops.  Bubbles arise exactly as in Fig. 3: a slow P (or
    a not-yet-ready prompt handoff, `token_gate`) head-of-line-blocks every
    stage behind it.

    Modeled dependencies:
      stage occupancy — fixed per-stage order = emission order;
      activation      — (mb, step, s) starts after (mb, step, s−1);
      sampled token   — T_i at stage 0 starts after T_{i−1} cleared the LAST
                        stage (the next input token is sampled there);
      admission       — a queued job takes a slot only when the slot frees.

    Returns (trace, items in execution order) — `exec_cb` lets the real
    cluster run actual compute in this exact order.
    """
    max_inflight = max_inflight or depth
    trace = Trace()
    items: List[Item] = []
    queue = sorted(jobs, key=lambda j: (j.arrival, j.mb))
    slots: List[Optional[dict]] = [None] * max_inflight
    qi = 0
    stage_free = [0.0] * depth

    def emit(kind: str, mb: int, step: int, release: float, dur: float) -> float:
        prev_fin = release
        for s in range(depth):
            it = Item(pipeline, mb, kind, step, s, dur)
            start = max(prev_fin, stage_free[s])
            fin = start + it.duration
            stage_free[s] = fin
            trace.start[it.key] = start
            trace.finish[it.key] = fin
            items.append(it)
            if exec_cb is not None:
                exec_cb(it)
            prev_fin = fin
        return prev_fin

    active = 0
    while True:
        for q in range(max_inflight):
            if slots[q] is None and qi < len(queue):
                j = queue[qi]; qi += 1
                slots[q] = {"job": j, "step": -1, "release": j.arrival}
                active += 1
        if active == 0:
            break
        for q in range(max_inflight):
            st = slots[q]
            if st is None:
                continue
            j = st["job"]
            if st["step"] < 0:  # prompt (or external handoff gate)
                if do_prompt:
                    st["release"] = emit("P", j.mb, 0, st["release"], p_dur)
                else:
                    gate = (token_gate or {}).get(j.mb, j.arrival)
                    st["release"] = max(st["release"], gate)
                st["step"] = 0
                if not do_tokens:
                    slots[q] = None
                    active -= 1
                continue
            i = st["step"]
            st["release"] = emit("T", j.mb, i, st["release"], t_dur)
            st["step"] += 1
            if st["step"] >= j.n_tokens:
                slots[q] = None
                active -= 1
    return trace, items


def build_pipeline_items(engine: EventEngine, jobs: List[Job], *,
                         pipeline: str, depth: int, p_dur: float, t_dur: float,
                         max_inflight: Optional[int] = None,
                         do_prompt: bool = True, do_tokens: bool = True,
                         token_release: Optional[Dict[int, float]] = None,
                         token_extra_dep: Optional[Dict[int, Key]] = None,
                         t_extra_delay: float = 0.0) -> None:
    """Emit P/T items + deps for one pipeline.

    token_release/token_extra_dep: per-mb gate for T_0 (e.g. prompt handoff
    from a disaggregated prompt pipeline, incl. stream delay).
    max_inflight: admission control — mb i is gated on mb (i − max_inflight)
    clearing stage 0 of its final step.
    """
    max_inflight = max_inflight or depth
    for idx, job in enumerate(jobs):
        adm_deps: List[Key] = []
        release = job.arrival
        if idx >= max_inflight:
            prev = jobs[idx - max_inflight]
            last_kind = "T" if do_tokens else "P"
            last_step = prev.n_tokens - 1 if do_tokens else 0
            adm_deps.append((pipeline, prev.mb, last_kind, last_step, 0))
        if do_prompt:
            for s in range(depth):
                deps = list(adm_deps) if s == 0 else []
                if s > 0:
                    deps.append((pipeline, job.mb, "P", 0, s - 1))
                engine.add(Item(pipeline, job.mb, "P", 0, s, p_dur),
                           deps=deps, release=release)
        if do_tokens:
            for i in range(job.n_tokens):
                for s in range(depth):
                    deps: List[Key] = []
                    rel = release
                    if s > 0:
                        deps.append((pipeline, job.mb, "T", i, s - 1))
                    if i > 0:
                        deps.append((pipeline, job.mb, "T", i - 1, s))
                    else:
                        if do_prompt:
                            deps.append((pipeline, job.mb, "P", 0, depth - 1 if s == 0 else s))
                        if s == 0:
                            if token_extra_dep and job.mb in token_extra_dep:
                                deps.append(token_extra_dep[job.mb])
                            if token_release and job.mb in token_release:
                                rel = max(rel, token_release[job.mb])
                            deps.extend(adm_deps if not do_prompt else [])
                    engine.add(Item(pipeline, job.mb, "T", i, s, t_dur),
                               deps=deps, release=rel,
                               extra_delay=t_extra_delay if s == 0 and i == 0 else 0.0)
