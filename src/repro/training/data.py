"""Deterministic synthetic data pipeline.

Generates a learnable "language": each sequence interleaves a small set of
fixed n-gram motifs (predictable — the model's loss drops fast) with uniform
noise tokens.  Sharding is by (host, step): every host derives its shard from
(seed, host_id, step) so restarts resume bit-identically mid-epoch — the data
half of fault-tolerant training.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class SyntheticDataPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_motifs: int = 32
    motif_len: int = 16
    noise_prob: float = 0.1
    host_id: int = 0
    num_hosts: int = 1
    family: str = "dense"
    d_model: int = 0           # for vlm / encdec stub embeddings
    num_patches: int = 0
    src_len: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.motifs = rng.integers(2, self.vocab_size,
                                   (self.num_motifs, self.motif_len)).astype(np.int32)
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for `step` on this host (pure function of (seed, host, step))."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host_id, step]))
        b, s = self.local_batch, self.seq_len
        n_mot = s // self.motif_len + 2
        ids = rng.integers(0, self.num_motifs, (b, n_mot))
        seq = self.motifs[ids].reshape(b, -1)[:, :s + 1]
        noise = rng.random((b, s + 1)) < self.noise_prob
        rand = rng.integers(2, self.vocab_size, (b, s + 1)).astype(np.int32)
        seq = np.where(noise, rand, seq)
        batch = {"tokens": seq[:, :-1].astype(np.int32),
                 "targets": seq[:, 1:].astype(np.int32),
                 "loss_mask": np.ones((b, s), np.float32)}
        if self.family == "vlm" and self.num_patches:
            batch["patch_embeds"] = rng.standard_normal(
                (b, self.num_patches, self.d_model)).astype(np.float32)
        if self.family == "encdec" and self.src_len:
            batch["src_embeds"] = rng.standard_normal(
                (b, self.src_len, self.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
