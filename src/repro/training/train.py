"""Training step factory: loss+grad (+ optional microbatched accumulation),
AdamW update, metrics.  Sharding is applied at the jit boundary by the
launcher (launch/train.py); remat is a model flag.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWState, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_accum: int = 1        # microbatches per step (sequential, in-jit)


def make_train_step(model, tcfg: TrainConfig = TrainConfig()):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  With grad_accum > 1, the leading batch dim is split into
    microbatches accumulated inside one jit (a lax.scan, so HLO stays small).
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state: AdamWState, batch):
        if tcfg.grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            n = tcfg.grad_accum

            def split(x):
                b = x.shape[0]
                return x.reshape(n, b // n, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_l, acc_g = acc
                return (acc_l + l / n,
                        jax.tree.map(lambda a, b_: a + b_ / n, acc_g, g)), None

            zero = (jnp.float32(0.0),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(body, zero, micro)
        params, opt_state, om = adamw_update(
            grads, opt_state, params, lr=tcfg.lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay, clip_norm=tcfg.clip_norm)
        metrics = {"loss": loss.astype(jnp.float32), **om}
        return params, opt_state, metrics

    return train_step
