"""Sharded checkpointing with atomic manifests and auto-resume.

Layout: <dir>/step_<N>/ holds one .npy per pytree leaf (path-encoded) plus a
manifest.json written LAST via atomic rename — a crash mid-save can never
yield a readable-but-torn checkpoint, and restart code simply picks the
largest step whose manifest exists.  This is the training half of the paper's
fault-tolerance story (serving state is covered by KV replication).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    if hasattr(tree, "_asdict"):  # NamedTuple (AdamWState)
        out = []
        for k, v in tree._asdict().items():
            out.extend(_flatten(v, f"{prefix}{k}/"))
        return out
    return [(prefix[:-1], tree)]


def save_checkpoint(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for path, arr in leaves:
        arr = np.asarray(arr)
        fname = path.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"path": path, "file": fname,
                                   "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json.tmp"), "w") as f:
        json.dump(manifest, f)
    os.replace(os.path.join(tmp, "manifest.json.tmp"),
               os.path.join(tmp, "manifest.json"))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_valid_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def _valid_steps(ckpt_dir: str) -> List[int]:
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _valid_steps(ckpt_dir)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template, step: Optional[int] = None):
    """Restore into the structure of `template` (pytree of arrays)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path: Dict[str, np.ndarray] = {}
    for leaf in manifest["leaves"]:
        by_path[leaf["path"]] = np.load(os.path.join(d, leaf["file"]))

    flat_template = _flatten(template)
    values = {path: by_path[path] for path, _ in flat_template}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{prefix}{k}/") for k in tree}
        if hasattr(tree, "_asdict"):
            return type(tree)(**{k: rebuild(v, f"{prefix}{k}/")
                                 for k, v in tree._asdict().items()})
        arr = values[prefix[:-1]]
        return jax.numpy.asarray(arr, dtype=tree.dtype)

    return rebuild(template), step
