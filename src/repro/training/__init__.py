from repro.training.optimizer import adamw_init, adamw_update, global_norm
from repro.training.train import make_train_step, TrainConfig
from repro.training.data import SyntheticDataPipeline
from repro.training.checkpoint import save_checkpoint, restore_checkpoint, latest_step

__all__ = ["adamw_init", "adamw_update", "global_norm", "make_train_step",
           "TrainConfig", "SyntheticDataPipeline", "save_checkpoint",
           "restore_checkpoint", "latest_step"]
