from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import SyntheticDataPipeline
from repro.training.optimizer import adamw_init, adamw_update, global_norm
from repro.training.train import TrainConfig, make_train_step

__all__ = ["adamw_init", "adamw_update", "global_norm", "make_train_step",
           "TrainConfig", "SyntheticDataPipeline", "save_checkpoint",
           "restore_checkpoint", "latest_step"]
