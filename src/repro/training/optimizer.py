"""AdamW (pure JAX), global-norm clipping, and compressed gradient collectives.

Optimizer moments are f32 regardless of parameter dtype; the update is
computed in f32 and cast back.  `compressed_allreduce` (int8 + per-tensor
scale, all-gather + local dequant-sum inside shard_map) is the beyond-paper
distributed-optimization trick — 4× less cross-DP gradient traffic than f32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state: AdamWState, params, *, lr: float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        new_p = p.astype(jnp.float32) - lr * (mhat / (jnp.sqrt(vhat) + eps)
                                              + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.m, state.v,
                        is_leaf=lambda x: isinstance(x, jax.Array))
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Compressed gradient all-reduce (beyond-paper distributed-optimization trick)
# ---------------------------------------------------------------------------

def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_allreduce(x, axis_name: str):
    """int8 all-gather + local dequant-sum over `axis_name` (inside shard_map).

    Moves 1/4 the bytes of an f32 all-reduce (1/2 of bf16) at the cost of one
    quantization error per participant — acceptable for gradients when paired
    with error-tolerant optimizers (Adam normalizes per-coordinate anyway).
    """
    q, scale = quantize_int8(x.astype(jnp.float32))
    qs = jax.lax.all_gather(q, axis_name)                 # [n, ...] int8
    ss = jax.lax.all_gather(scale, axis_name)             # [n] f32
    return jnp.sum(qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * q.ndim),
                   axis=0)
