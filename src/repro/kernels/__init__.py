# Pallas TPU kernels for the paper's compute/DMA hot spots:
#   kv_pack / kv_unpack   — DéjàVuLib buffered copies (paper §4.1 opt-1)
#   flash_attention       — prefill (compute-bound phase)
#   decode_attention      — token generation (bandwidth-bound phase),
#                           incl. paged_decode_attention (block-table gather)
#   paged_prefill         — chunked prefill over the paged pool (a Q chunk
#                           attends over a pool-resident prefix + itself)
#   ssd_scan              — Mamba-2 chunked SSD (assigned-arch substrate)
# ops.py holds the jit'd wrappers; ref.py the pure-jnp oracles.
