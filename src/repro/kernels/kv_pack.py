"""kv_pack / kv_unpack — the DéjàVuLib "buffered copies" kernels (paper §4.1 opt-1).

GPU original: token generation updates one tiny non-contiguous KV slice per
layer; issuing L×B small cudaMemcpys dominates streaming cost, so DéjàVu
aggregates them into one contiguous GPU buffer first.

TPU adaptation: one `pallas_call` whose grid covers (layer × batch × token
blocks) gathers the strided window of the stacked cache [L,B,S,H,D] into a
single dense staging buffer [L,B,W,H,D] in one HBM pass — the buffer then
leaves the chip as a single contiguous DMA.  `kv_unpack` is the inverse
scatter (restore / swap-in), aliasing the cache operand for in-place update.

The dynamic token offset arrives via scalar prefetch; block alignment of the
offset is a DMA-alignment requirement enforced by the cache manager
(`repro.core.dejavulib`), which rounds windows to ``token_block``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(t0_ref, src_ref, dst_ref):
    del t0_ref
    dst_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("width", "token_block", "interpret"))
def kv_pack(cache, t0, *, width: int, token_block: int = 8, interpret: bool = True):
    """Pack cache[:, :, t0:t0+width] into a contiguous buffer.

    cache: [L,B,S,H,D]; t0: scalar int32, multiple of token_block.
    Returns [L,B,width,H,D].
    """
    l, b, s, h, d = cache.shape
    bt = min(token_block, width)
    assert width % bt == 0, (width, bt)
    grid = (l, b, width // bt)
    spec_in = pl.BlockSpec((1, 1, bt, h, d),
                           lambda li, bi, i, t0r: (li, bi, t0r[0] // bt + i, 0, 0))
    spec_out = pl.BlockSpec((1, 1, bt, h, d), lambda li, bi, i, t0r: (li, bi, i, 0, 0))
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=[spec_in], out_specs=spec_out),
        out_shape=jax.ShapeDtypeStruct((l, b, width, h, d), cache.dtype),
        interpret=interpret,
    )(jnp.asarray(t0, jnp.int32).reshape(1), cache)


@functools.partial(jax.jit, static_argnames=("width", "token_block", "interpret"))
def kv_pack_ragged(cache, starts, *, width: int, token_block: int = 8,
                   interpret: bool = True):
    """Fused-round buffered copy: pack ONE window per batch row, each at its
    own token offset — batch row b yields cache[:, b, starts[b]:starts[b]+width].

    cache: [L,B,S,H,D]; starts: [B] int32, each a multiple of token_block
    (the cache manager's DMA alignment, like `kv_pack`'s scalar t0).
    Returns [L,B,width,H,D].  One launch replaces the B separate `kv_pack`
    calls a per-sequence writeback would issue — the multi-sequence analogue
    of aggregating L×B small copies into one pass.
    """
    l, b, s, h, d = cache.shape
    bt = min(token_block, width)
    assert width % bt == 0, (width, bt)
    grid = (l, b, width // bt)
    spec_in = pl.BlockSpec(
        (1, 1, bt, h, d), lambda li, bi, i, st: (li, bi, st[bi] // bt + i, 0, 0))
    spec_out = pl.BlockSpec((1, 1, bt, h, d),
                            lambda li, bi, i, st: (li, bi, i, 0, 0))
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=[spec_in],
            out_specs=spec_out),
        out_shape=jax.ShapeDtypeStruct((l, b, width, h, d), cache.dtype),
        interpret=interpret,
    )(jnp.asarray(starts, jnp.int32).reshape(-1), cache)


def _scatter_kernel(t0_ref, buf_ref, cache_ref, out_ref):
    del t0_ref, cache_ref
    out_ref[...] = buf_ref[...]


@functools.partial(jax.jit, static_argnames=("token_block", "interpret"),
                   donate_argnums=(0,))
def kv_unpack(cache, buf, t0, *, token_block: int = 8, interpret: bool = True):
    """Scatter a contiguous buffer back into the cache window at t0 (in-place).

    cache: [L,B,S,H,D] (donated); buf: [L,B,W,H,D]; t0 multiple of token_block.
    """
    l, b, s, h, d = cache.shape
    width = buf.shape[2]
    bt = min(token_block, width)
    assert width % bt == 0, (width, bt)
    grid = (l, b, width // bt)
    spec_buf = pl.BlockSpec((1, 1, bt, h, d), lambda li, bi, i, t0r: (li, bi, i, 0, 0))
    spec_cache = pl.BlockSpec((1, 1, bt, h, d),
                              lambda li, bi, i, t0r: (li, bi, t0r[0] // bt + i, 0, 0))
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=[spec_buf, spec_cache], out_specs=spec_cache),
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        input_output_aliases={2: 0},  # cache operand (after scalar) -> output
        interpret=interpret,
    )(jnp.asarray(t0, jnp.int32).reshape(1), buf.astype(cache.dtype), cache)
