"""Chunked SSD (Mamba-2) scan kernel.

The SSD dual form turns the recurrence into per-chunk dense matmuls (MXU)
plus a tiny inter-chunk state recurrence.  Grid = (B, num_chunks) with chunks
innermost-sequential; the running state h [nh,hd,N] (f32) lives in VMEM
scratch and carries across chunk steps.  An optional initial state h0 supports
DéjàVu prefill-resume (continuing from a streamed-in SSM state).

Per chunk (Q tokens): intra-chunk (C·Bᵀ ⊙ decay-mask) @ X and the state
contribution/readout — all [Q×Q] / [Q×N] / [N×hd] matmuls, 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, hout_ref, h_scr, *, rep, chunk):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)          # [Q, nh, hd]
    dt = dt_ref[0, 0].astype(jnp.float32)        # [Q, nh]
    a = a_ref[...].astype(jnp.float32)           # [nh]
    bm = b_ref[0, 0].astype(jnp.float32)         # [Q, G, N]
    cm = c_ref[0, 0].astype(jnp.float32)         # [Q, G, N]
    h = h_scr[...]                               # [nh, hd, N]

    da = dt * a                                  # [Q, nh]
    da_cum = jnp.cumsum(da, axis=0)              # inclusive

    # intra-chunk (mask before exp: see models/ssm.py note on inf·0 grads)
    li = da_cum[:, None, :] - da_cum[None, :, :]          # [i, j, nh]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.exp(jnp.where(tri[:, :, None], li, -1e30))  # [i, j, nh]
    bh = jnp.repeat(bm, rep, axis=1)                       # [Q, nh, N]
    ch = jnp.repeat(cm, rep, axis=1)
    cb = jnp.einsum("ihn,jhn->ijh", ch, bh)                # [i, j, nh]
    scores = cb * lmat * dt[None, :, :]                    # dt_j
    y = jnp.einsum("ijh,jhd->ihd", scores, x)

    # inter-chunk: readout of incoming state, then state update
    y += jnp.einsum("ihn,hdn,ih->ihd", ch, h, jnp.exp(da_cum))
    decay_states = jnp.exp(da_cum[-1, :][None, :] - da_cum)          # [j, nh]
    h_new = h * jnp.exp(da_cum[-1, :])[:, None, None] + \
        jnp.einsum("jhn,jh,jh,jhd->hdn", bh, decay_states, dt, x)
    h_scr[...] = h_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == pl.num_programs(1) - 1)
    def _emit():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_neg, bmat, cmat, h0=None, *, chunk: int = 128,
             interpret: bool = True):
    """x: [B,S,nh,hd]; dt: [B,S,nh]; a_neg: [nh]; bmat/cmat: [B,S,G,N].

    Returns (y [B,S,nh,hd], h_final [B,nh,hd,N] f32).  S padded to chunk."""
    b, s, nh, hd = x.shape
    g, n = bmat.shape[-2:]
    rep = nh // g
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // q
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, n), jnp.float32)

    xs = x.reshape(b, nc, q, nh, hd)
    dts = dt.reshape(b, nc, q, nh)
    bs = bmat.reshape(b, nc, q, g, n)
    cs = cmat.reshape(b, nc, q, g, n)
    grid = (b, nc)

    y, hout = pl.pallas_call(
        functools.partial(_ssd_kernel, rep=rep, chunk=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, nh, hd), lambda bi, ic: (bi, ic, 0, 0, 0)),
            pl.BlockSpec((1, 1, q, nh), lambda bi, ic: (bi, ic, 0, 0)),
            pl.BlockSpec((nh,), lambda bi, ic: (0,)),
            pl.BlockSpec((1, 1, q, g, n), lambda bi, ic: (bi, ic, 0, 0, 0)),
            pl.BlockSpec((1, 1, q, g, n), lambda bi, ic: (bi, ic, 0, 0, 0)),
            pl.BlockSpec((1, nh, hd, n), lambda bi, ic: (bi, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, nh, hd), lambda bi, ic: (bi, ic, 0, 0, 0)),
            pl.BlockSpec((1, nh, hd, n), lambda bi, ic: (bi, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, q, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((nh, hd, n), jnp.float32)],
        interpret=interpret,
    )(xs, dts, a_neg, bs, cs, h0)
    return y.reshape(b, sp, nh, hd)[:, :s], hout
