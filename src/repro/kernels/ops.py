"""jit'd dispatch wrappers around the Pallas kernels.

Models call these via ``backend="pallas"``.  On this CPU container the
kernels execute in interpret mode (`INTERPRET=True`); on TPU the flag flips
to compiled mode.  Wrappers adapt the models' masked-attention interface to
the kernels' position-based one and fall back to the jnp reference for
shapes the kernels don't cover (e.g. additive-bias attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import (batched_decode_attention,
                                            decode_attention,
                                            paged_decode_attention)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kv_pack import kv_pack, kv_pack_ragged, kv_unpack
from repro.kernels.paged_prefill import paged_prefill_attention
from repro.kernels.ssd_scan import ssd_scan

# flip to False on real TPU devices
INTERPRET = jax.default_backend() != "tpu"


def attention_auto(q, k, v, mask=None, bias=None):
    """Prefill attention entry point.  Uses the flash kernel for the plain
    causal case; falls back to the reference for exotic masks/bias."""
    b, sq, hq, d = q.shape
    plain_causal = bias is None and (mask is None or _is_plain_causal(mask, sq, k.shape[1]))
    if plain_causal:
        return flash_attention(q, k, v, causal=mask is not None, interpret=INTERPRET)
    from repro.models.attention import attend
    return attend(q, k, v, mask=mask, bias=bias, backend="xla")


def _is_plain_causal(mask, sq, skv) -> bool:
    # static structural check only (trace-safe): 2-D mask of full extent
    return mask.ndim == 2 and mask.shape == (sq, skv) and sq == skv


def decode_attention_auto(q, k_cache, v_cache, mask):
    """Decode attention entry point.  q: [B,1,Hq,D]; mask: [1,Skv] bool."""
    valid = mask[0] if mask.ndim == 2 else mask
    out = decode_attention(q[:, 0], k_cache, v_cache, valid, interpret=INTERPRET)
    return out[:, None]


def batched_decode_attention_auto(q, k_cache, v_cache, lengths, *,
                                  window=0, num_meta: int = 0, alibi=None):
    """Fused-round decode attention entry point: one launch, B sequences,
    ragged per-sequence lengths.  q: [B,Hq,D]; k/v: [B,S,Hkv,D].

    `window` (static or traced per-layer int32; 0 = full attention) becomes
    per-sequence window starts max(lengths - window, 0); `alibi` [Hq] slopes
    ride scalar prefetch into the kernel's additive bias."""
    win_starts = None
    if not (isinstance(window, int) and window == 0):
        w = jnp.asarray(window, jnp.int32)
        win_starts = jnp.where(w > 0, jnp.maximum(lengths - w, 0), 0)
    slopes = None if alibi is None else jnp.asarray(alibi, jnp.float32)
    return batched_decode_attention(q, k_cache, v_cache, lengths,
                                    win_starts, slopes,
                                    num_meta=int(num_meta),
                                    interpret=INTERPRET)


def paged_decode_attention_auto(q, k_pages, v_pages, block_tables, lengths):
    """Paged decode attention entry point.  q: [B,1,Hq,D] or [B,Hq,D]."""
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    out = paged_decode_attention(q, k_pages, v_pages, block_tables, lengths,
                                 interpret=INTERPRET)
    return out[:, None] if squeeze else out


def paged_prefill_attention_auto(q, k_pages, v_pages, block_tables, q_starts,
                                 q_lens):
    """Chunked paged-prefill entry point.  q: [B,C,Hq,D]; the chunk's own
    K/V window must already be scattered into the pages (via kv_pack)."""
    return paged_prefill_attention(q, k_pages, v_pages, block_tables,
                                   q_starts, q_lens, interpret=INTERPRET)


def ssd_auto(x, dt, a_neg, bmat, cmat, chunk=128, h0=None):
    return ssd_scan(x, dt, a_neg, bmat, cmat, h0=h0, chunk=min(chunk, x.shape[1]),
                    interpret=INTERPRET)


def kv_pack_auto(cache, t0, width, token_block: int = 8):
    return kv_pack(cache, t0, width=width, token_block=token_block,
                   interpret=INTERPRET)


def kv_pack_ragged_auto(cache, starts, width, token_block: int = 8):
    """Multi-sequence buffered copy: one window per batch row at per-row
    offsets (the fused-round KV writeback)."""
    return kv_pack_ragged(cache, starts, width=width, token_block=token_block,
                          interpret=INTERPRET)


def kv_unpack_auto(cache, buf, t0, token_block: int = 8):
    return kv_unpack(cache, buf, t0, token_block=token_block,
                     interpret=INTERPRET)
