"""Pure-jnp oracles for every Pallas kernel (tested via assert_allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# kv_pack / kv_unpack — DéjàVuLib buffered copies
# ---------------------------------------------------------------------------

def kv_pack_ref(cache, t0, width: int):
    """cache: [L,B,S,H,D] -> contiguous window [L,B,width,H,D] at t0."""
    return jax.lax.dynamic_slice_in_dim(cache, t0, width, axis=2)


def kv_unpack_ref(cache, buf, t0):
    """Scatter buf [L,B,W,H,D] back into cache at token offset t0."""
    return jax.lax.dynamic_update_slice_in_dim(cache, buf.astype(cache.dtype), t0, axis=2)


# ---------------------------------------------------------------------------
# flash attention (prefill, causal, GQA)
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: [B,Sq,Hq,D]; k/v: [B,Skv,Hkv,D] -> [B,Sq,Hq,D].  f32 softmax."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, d)


# ---------------------------------------------------------------------------
# decode attention (single query vs long KV, validity mask)
# ---------------------------------------------------------------------------

def decode_attention_ref(q, k, v, kv_valid):
    """q: [B,Hq,D]; k/v: [B,S,Hkv,D]; kv_valid: [S] bool -> [B,Hq,D]."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k).astype(jnp.float32) * (d ** -0.5)
    scores = jnp.where(kv_valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v)
    return out.reshape(b, hq, d)


# ---------------------------------------------------------------------------
# batched decode attention (fused rounds: ragged per-sequence lengths)
# ---------------------------------------------------------------------------

def batched_decode_attention_ref(q, k, v, lengths, win_starts=None,
                                 slopes=None, *, num_meta: int = 0):
    """q: [B,Hq,D]; k/v: [B,S,Hkv,D]; lengths: [B] int32 (live tokens per
    sequence, incl. the new one) -> [B,Hq,D].  `decode_attention_ref` with a
    per-sequence validity mask — the dense oracle of the fused-round pass.

    win_starts: optional [B] int32 first non-meta slot each sequence may
    attend (0 = full attention); slots < num_meta are always-visible sinks.
    slopes: optional [Hq] f32 ALiBi slopes (query at position lengths[b]-1)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    pos = jnp.arange(s)[None, :]                                   # [1,S]
    valid = pos < lengths[:, None]                                 # [B,S]
    if win_starts is not None:
        valid &= (pos >= win_starts[:, None]) | (pos < num_meta)
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k).astype(jnp.float32) * (d ** -0.5)
    if slopes is not None:
        dist = ((lengths[:, None] - 1) - pos).astype(jnp.float32)  # [B,S]
        scores = scores - (slopes.reshape(hkv, g).astype(jnp.float32)
                           [None, :, :, None]
                           * jnp.maximum(dist, 0.0)[:, None, None, :])
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v)
    return out.reshape(b, hq, d)


# ---------------------------------------------------------------------------
# kv_pack_ragged — fused-round writeback (per-sequence window offsets)
# ---------------------------------------------------------------------------

def kv_pack_ragged_ref(cache, starts, width: int):
    """cache: [L,B,S,H,D]; starts: [B] -> [L,B,width,H,D], batch row b being
    the window cache[:, b, starts[b]:starts[b]+width]."""
    rows = [jax.lax.dynamic_slice_in_dim(cache[:, b], int(starts[b]), width,
                                         axis=1)
            for b in range(cache.shape[1])]
    return jnp.stack(rows, axis=1)


# ---------------------------------------------------------------------------
# paged decode attention (block-table gather over a shared page pool)
# ---------------------------------------------------------------------------

def paged_gather_ref(pages, block_tables):
    """pages: [N,bs,H,D]; block_tables: [B,max_blocks] -> dense [B,S,H,D]."""
    b, mb = block_tables.shape
    _, bs, h, d = pages.shape
    gathered = jnp.take(pages, block_tables.reshape(-1), axis=0)
    return gathered.reshape(b, mb * bs, h, d)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """q: [B,Hq,D]; k/v_pages: [N,bs,Hkv,D]; block_tables: [B,max_blocks];
    lengths: [B] -> [B,Hq,D].  Gathers pages dense, then masked softmax."""
    b, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    k = paged_gather_ref(k_pages, block_tables)
    v = paged_gather_ref(v_pages, block_tables)
    s = k.shape[1]
    valid = jnp.arange(s)[None, :] < lengths[:, None]          # [B,S]
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k).astype(jnp.float32) * (d ** -0.5)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v)
    return out.reshape(b, hq, d)


# ---------------------------------------------------------------------------
# paged prefill attention (chunk of Q tokens vs paged prefix + itself)
# ---------------------------------------------------------------------------

def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables, q_starts,
                                q_lens):
    """q: [B,C,Hq,D] chunk of new queries; query i of sequence b sits at
    absolute position ``q_starts[b] + i`` and attends causally over the
    paged KV [0, q_starts[b] + q_lens[b]) (the chunk's own K/V must already
    be resident in the pages).  k/v_pages: [N,bs,Hkv,D]; block_tables:
    [B,max_blocks]; q_lens: [B] valid queries per chunk -> [B,C,Hq,D].
    Rows past q_lens[b] are don't-care (the caller slices them off)."""
    b, c, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    k = paged_gather_ref(k_pages, block_tables)
    v = paged_gather_ref(v_pages, block_tables)
    s = k.shape[1]
    qpos = q_starts[:, None] + jnp.arange(c)[None, :]              # [B,C]
    kvpos = jnp.arange(s)[None, None, :]                           # [1,1,S]
    valid = (kvpos <= qpos[:, :, None]) \
        & (kvpos < (q_starts + q_lens)[:, None, None])             # [B,C,S]
    qg = q.reshape(b, c, hkv, g, d)
    scores = jnp.einsum("bchgd,bkhd->bhgck", qg, k).astype(jnp.float32) * (d ** -0.5)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgck,bkhd->bchgd", probs, v)
    return out.reshape(b, c, hq, d)


# ---------------------------------------------------------------------------
# SSD — sequential recurrence oracle (independent of the chunked algorithm)
# ---------------------------------------------------------------------------

def ssd_sequential_ref(x, dt, a_neg, bmat, cmat, h0=None):
    """Token-by-token recurrence.  x: [B,S,nh,hd]; dt: [B,S,nh];
    a_neg: [nh]; bmat/cmat: [B,S,G,N].  Returns (y, h_final)."""
    b, s, nh, hd = x.shape
    g, n = bmat.shape[-2:]
    rep = nh // g
    h = jnp.zeros((b, nh, hd, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    a32 = a_neg.astype(jnp.float32)     # keep the scan carry f32 under x64

    def step(h, inp):
        xt, dtt, bt, ct = inp                      # [b,nh,hd], [b,nh], [b,g,n]
        bt_h = jnp.repeat(bt, rep, axis=1).astype(jnp.float32)
        ct_h = jnp.repeat(ct, rep, axis=1).astype(jnp.float32)
        da = jnp.exp(dtt.astype(jnp.float32) * a32)
        h = h * da[:, :, None, None] + (dtt.astype(jnp.float32)[:, :, None, None]
                                        * xt.astype(jnp.float32)[:, :, :, None]
                                        * bt_h[:, :, None, :])
        y = jnp.einsum("bhdn,bhn->bhd", h, ct_h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
