"""Single-token decode attention (memory-bandwidth hot-spot of token generation).

Flash-decode style: the KV sequence is tiled into blocks streamed HBM→VMEM;
the grid iterates (B, Hkv, kv_blocks) with the per-group online-softmax state
(m, l, acc over the G query heads of the KV head's group) carried in VMEM
scratch.  A validity mask [S] (from absolute slot positions — supports ring
buffers / partially-filled caches) is blocked along with K/V.

This is the kernel the DéjàVu T-workers run every step; its arithmetic
intensity is ~1 FLOP/byte so the roofline bound is HBM bandwidth — block
sizes are chosen to keep the KV stream dense (bk×D tiles, 128-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)            # [G, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bk, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = (q @ k.T) * scale                                # [G, bk]
    valid = valid_ref[...] != 0                          # [bk]
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(ik == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0, 0, :, :] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale, block_size):
    bi = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)            # [G, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bs, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = (q @ k.T) * scale                                # [G, bs]
    # slot j of logical block ik holds token ik*bs + j; valid iff < seq length
    g = s.shape[0]
    slot = jax.lax.broadcasted_iota(jnp.int32, (g, block_size), 1)
    valid = ik * block_size + slot < len_ref[bi]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(ik == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0, 0, :, :] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           interpret: bool = True):
    """Decode attention over a paged KV cache (block-table gather).

    q: [B,Hq,D]; k_pages/v_pages: [N,bs,Hkv,D] (shared page pool);
    block_tables: [B,max_blocks] int32 — logical block j of sequence b lives
    in page block_tables[b,j] (pad unused tail entries with any valid page id,
    conventionally 0); lengths: [B] int32 live token counts.  -> [B,Hq,D].

    The tables + lengths ride scalar prefetch so each (b, h, j) grid step
    DMAs exactly one page — the gather never materializes a dense cache.
    """
    b, hq, d = q.shape
    n, bs, hkv, _ = k_pages.shape
    g = hq // hkv
    max_blocks = block_tables.shape[1]
    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, max_blocks)

    q_spec = pl.BlockSpec((1, 1, g, d), lambda bi, h, ik, bt, ln: (bi, h, 0, 0))
    kv_spec = pl.BlockSpec((1, bs, 1, d),
                           lambda bi, h, ik, bt, ln: (bt[bi, ik], 0, h, 0))
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=d ** -0.5, block_size=bs),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=grid,
            in_specs=[q_spec, kv_spec, kv_spec],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bi, h, ik, bt, ln: (bi, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ]),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(b, hq, d)


def _batched_decode_kernel(len_ref, ws_ref, slope_ref, q_ref, k_ref, v_ref,
                           o_ref, m_ref, l_ref, acc_ref, *, scale, block_k,
                           num_meta, use_bias):
    bi = pl.program_id(0)
    h = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)            # [G, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bk, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = (q @ k.T) * scale                                # [G, bk]
    # ragged batch: slot j of tile ik is absolute position ik*bk + j, valid
    # iff it is below THIS sequence's live length (vs the shared [S] mask of
    # `decode_attention`)
    g = s.shape[0]
    slot = jax.lax.broadcasted_iota(jnp.int32, (g, block_k), 1)
    abs_pos = ik * block_k + slot                        # [G, bk]
    if use_bias:
        # ALiBi: the query sits at position len-1; masked slots get NEG_INF
        # below, so the bias there is don't-care
        dist = (len_ref[bi] - 1) - abs_pos
        s = s - slope_ref[h][:, None] * jnp.maximum(dist, 0).astype(jnp.float32)
    valid = abs_pos < len_ref[bi]
    # sliding window: only slots at/after this sequence's window start attend
    # (start 0 = windowless no-op), except the always-visible meta sinks
    valid &= (abs_pos >= ws_ref[bi]) | (abs_pos < num_meta)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(ik == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0, 0, :, :] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "num_meta", "interpret"))
def batched_decode_attention(q, k, v, lengths, win_starts=None, slopes=None, *,
                             block_k: int = 512, num_meta: int = 0,
                             interpret: bool = True):
    """Fused-round decode attention: every sequence of the batch advances one
    step in ONE kernel launch, each masked to its OWN live length.

    q: [B,Hq,D]; k/v: [B,S,Hkv,D] (per-sequence caches padded to a common S —
    the densified block-table gather of the fused live path); lengths: [B]
    int32 live token counts INCLUDING the new token -> [B,Hq,D].

    win_starts: optional [B] int32 per-sequence sliding-window start (the
    first non-meta slot allowed to attend; 0 = full attention for that
    sequence — e.g. a full-attn layer of a window mix).  Slots below the
    static `num_meta` are always-visible attention sinks.  slopes: optional
    [Hq] f32 ALiBi slopes; the query sits at position lengths[b]-1, so the
    bias at slot j is -slope * max(lengths[b]-1-j, 0), matching the XLA
    path's `alibi_bias`.

    This is `decode_attention` with the validity mask made per-sequence
    (ragged lengths) instead of one shared [S] vector, so one launch serves
    the whole fused round.  Lengths, window starts, and slopes ride scalar
    prefetch like the paged kernel's block tables.
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    bk = min(block_k, s)
    pk = (-s) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, (s + pk) // bk)
    use_bias = slopes is not None
    if win_starts is None:
        win_starts = jnp.zeros((b,), jnp.int32)
    slopes_hg = (jnp.asarray(slopes, jnp.float32).reshape(hkv, g)
                 if use_bias else jnp.zeros((hkv, g), jnp.float32))

    q_spec = pl.BlockSpec((1, 1, g, d),
                          lambda bi, h, ik, ln, ws, sl: (bi, h, 0, 0))
    kv_spec = pl.BlockSpec((1, bk, 1, d),
                           lambda bi, h, ik, ln, ws, sl: (bi, ik, h, 0))
    out = pl.pallas_call(
        functools.partial(_batched_decode_kernel, scale=d ** -0.5, block_k=bk,
                          num_meta=num_meta, use_bias=use_bias),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3, grid=grid,
            in_specs=[q_spec, kv_spec, kv_spec],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bi, h, ik, ln, ws, sl: (bi, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ]),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(lengths, jnp.int32), jnp.asarray(win_starts, jnp.int32),
      slopes_hg, qg, k, v)
    return out.reshape(b, hq, d)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, kv_valid, *, block_k: int = 512, interpret: bool = True):
    """q: [B,Hq,D]; k/v: [B,S,Hkv,D]; kv_valid: [S] bool -> [B,Hq,D]."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    bk = min(block_k, s)
    pk = (-s) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    valid = jnp.pad(kv_valid.astype(jnp.int32), (0, pk))
    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, (s + pk) // bk)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=d ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, h, ik: (bi, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, h, ik: (bi, ik, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, h, ik: (bi, ik, h, 0)),
            pl.BlockSpec((bk,), lambda bi, h, ik: (ik,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, h, ik: (bi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, valid)
    return out.reshape(b, hq, d)
