"""Causal GQA flash attention (prefill compute hot-spot).

Grid (B, Hq, num_q_blocks, num_kv_blocks) with the KV dimension innermost; the
online-softmax running max / sum / accumulator live in VMEM scratch and carry
across KV blocks.  Blocks are 128-aligned on the MXU contraction dims.  GQA is
expressed in the K/V index_map (q-head h reads kv-head h // group).

Validated against `ref.flash_attention_ref` in interpret mode on CPU; compiled
path targets TPU v5e (bf16 inputs, f32 softmax state).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale, bq, bk, causal, sq, skv):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)           # [bq, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = (q @ k.T) * scale                               # [bq, bk]

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < skv
    if causal:
        valid &= kpos <= qpos + (skv - sq)              # offset-causal
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(ik == pl.num_programs(3) - 1)
    def _emit():
        o_ref[0, :, 0, :] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: [B,Sq,Hq,D]; k/v: [B,Skv,Hkv,D] -> [B,Sq,Hq,D]."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    # pad seq dims to block multiples (masked out via kpos/qpos validity)
    pq = (-sq) % bq
    pk = (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    grid = (b, hq, (sq + pq) // bq, (skv + pk) // bk)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=d ** -0.5, bq=bq, bk=bk,
                          causal=causal, sq=sq, skv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda bi, h, iq, ik: (bi, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, h, iq, ik: (bi, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, h, iq, ik: (bi, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d), lambda bi, h, iq, ik: (bi, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq + pq, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]
