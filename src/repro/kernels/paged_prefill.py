"""Chunked paged-prefill attention (prefill-with-prefix-cache hot path).

A chunk of C new prompt tokens attends causally over (a) an arbitrary-length
prefix already resident in the paged pool — gathered per logical block
through the sequence's block table, exactly like `paged_decode_attention` —
and (b) itself.  The chunk's own K/V are written into pool pages *before*
the call (via `kv_pack` windows), so the kernel reads one uniform paged
stream: slot j of logical block ik holds absolute token ik*bs + j, valid for
query row at absolute position p iff slot <= p.

This is what makes prefix adoption strictly cheaper than a cold prefill:
the adopted prefix costs only the page reads it would cost anyway, while the
suffix runs in ceil(suffix/C) passes instead of one pipeline pass per token
(DéjàVu's prompt/token bimodality argument, applied to the recovery/reuse
path).  Grid (B, Hkv, kv_blocks) with the online-softmax state for the
chunk's C*G query rows carried in VMEM scratch; block tables + chunk
positions ride scalar prefetch so each grid step DMAs exactly one page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _paged_prefill_kernel(bt_ref, qs_ref, ql_ref, q_ref, k_ref, v_ref, o_ref,
                          m_ref, l_ref, acc_ref, *, scale, block_size, group):
    bi = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)            # [C*G, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bs, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = (q @ k.T) * scale                                # [C*G, bs]
    cg = s.shape[0]
    # row r is group member r%G of chunk-local query r//G, at absolute
    # position q_start + r//G; slot j of logical block ik is token ik*bs + j
    row = jax.lax.broadcasted_iota(jnp.int32, (cg, block_size), 0)
    qpos = qs_ref[bi] + row // group
    slot = ik * block_size + jax.lax.broadcasted_iota(jnp.int32,
                                                      (cg, block_size), 1)
    valid = (slot <= qpos) & (slot < qs_ref[bi] + ql_ref[bi])
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(ik == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0, 0, :, :] = (acc_ref[...]
                             / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(q, k_pages, v_pages, block_tables, q_starts,
                            q_lens, *, interpret: bool = True):
    """Chunked prefill attention over a paged KV cache.

    q: [B,C,Hq,D] — chunk of new queries; query i of sequence b sits at
    absolute position ``q_starts[b] + i``.  k_pages/v_pages: [N,bs,Hkv,D]
    shared page pool ALREADY holding the chunk's own K/V window (the caller
    scatters it via kv_pack before attending); block_tables: [B,max_blocks]
    int32 (pad unused tail entries with any valid page id); q_starts/q_lens:
    [B] int32 — prefix length and valid chunk length per sequence.
    -> [B,C,Hq,D]; rows past q_lens[b] are don't-care.
    """
    b, c, hq, d = q.shape
    n, bs, hkv, _ = k_pages.shape
    g = hq // hkv
    max_blocks = block_tables.shape[1]
    # [B,C,Hkv,G,D] -> [B,Hkv,C*G,D]: row r = (query r//G, group member r%G)
    qg = q.reshape(b, c, hkv, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b, hkv, c * g, d)
    grid = (b, hkv, max_blocks)

    q_spec = pl.BlockSpec((1, 1, c * g, d),
                          lambda bi, h, ik, bt, qs, ql: (bi, h, 0, 0))
    kv_spec = pl.BlockSpec((1, bs, 1, d),
                           lambda bi, h, ik, bt, qs, ql: (bt[bi, ik], 0, h, 0))
    out = pl.pallas_call(
        functools.partial(_paged_prefill_kernel, scale=d ** -0.5,
                          block_size=bs, group=g),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3, grid=grid,
            in_specs=[q_spec, kv_spec, kv_spec],
            out_specs=pl.BlockSpec((1, 1, c * g, d),
                                   lambda bi, h, ik, bt, qs, ql: (bi, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((c * g,), jnp.float32),
                pltpu.VMEM((c * g,), jnp.float32),
                pltpu.VMEM((c * g, d), jnp.float32),
            ]),
        out_shape=jax.ShapeDtypeStruct((b, hkv, c * g, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(q_starts, jnp.int32),
      jnp.asarray(q_lens, jnp.int32), qg, k_pages, v_pages)
    return out.reshape(b, hkv, c, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b, c, hq, d)
