"""Mixture-of-Experts FFN with sort-based, capacity-bounded token dispatch.

Design notes (TPU adaptation — see DESIGN.md):
  * We deliberately avoid the GShard one-hot dispatch einsum (O(T·E·C·d))
    whose FLOP cost dwarfs the useful expert compute.  Instead tokens are
    grouped by expert with a stable sort over [T·k] entries; positions within
    an expert come from `searchsorted` over the sorted expert ids; tokens
    beyond expert capacity are dropped (written to a spill row).
  * Expert compute is a batched matmul [E,C,d]×[E,d,ff] so the `experts`
    dimension shards cleanly over the `model` mesh axis (expert parallelism).
  * Useful FLOPs scale as T·k·(3·d·ff)·capacity_factor — the active-params
    regime — which keeps the roofline "useful compute" ratio honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation_fn, dense_init, logical_constraint, split_keys


def moe_init(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, kg, ku, kd = split_keys(key, 4)
    return {
        "router": dense_init(kr, (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(kg, (e, d, ff), dtype),
        "w_up": dense_init(ku, (e, d, ff), dtype),
        "w_down": dense_init(kd, (e, ff, d), dtype),
    }


def capacity(num_tokens: int, cfg) -> int:
    c = int(-(-num_tokens * cfg.experts_per_token * cfg.moe_capacity_factor // cfg.num_experts))
    return max(c, 1)


def moe_apply(x, p, cfg, return_aux: bool = False, drop: bool = True):
    """x: [T, d] flattened tokens -> [T, d] (+ aux load-balancing loss).

    ``drop=False`` dispatches with capacity T (provably lossless: a token's
    top-k experts are distinct, so no expert ever receives more than T
    entries).  Inference paths use it — capacity dropping is a TRAINING
    throughput device, and because `capacity(T)` depends on the pass's token
    count it couples a token's output to the batch composition, which would
    break the serving engine's token-identity invariant (fused batched
    rounds and packed prefill chunk-sets place the same token in passes of
    different sizes than the per-sequence oracle path)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = capacity(t, cfg) if drop else t
    act = activation_fn(cfg.activation)

    logits = (x.astype(jnp.float32) @ p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                    # [T, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # --- group (token, slot) entries by expert ------------------------------
    fe = eidx.reshape(-1)                                   # [T*k] expert id
    order = jnp.argsort(fe, stable=True)                    # group by expert
    se = fe[order]
    ar = jnp.arange(t * k, dtype=jnp.int32)
    pos_in_e = ar - jnp.searchsorted(se, se, side="left").astype(jnp.int32)
    keep = pos_in_e < c
    slot = jnp.where(keep, se * c + pos_in_e, e * c)        # spill row at E*C

    xr = jnp.take(x, order // k, axis=0)                    # [T*k, d]
    buf = jnp.zeros((e * c + 1, d), x.dtype).at[slot].set(xr, mode="drop")
    h = buf[: e * c].reshape(e, c, d)
    h = logical_constraint(h, "experts", None, None)

    # --- expert FFN (batched over experts; shards over `model`) -------------
    y = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
    y = act(y) * jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    y = logical_constraint(y, "experts", None, None)
    y = jnp.einsum("ecf,efd->ecd", y, p["w_down"])

    # --- combine back to tokens --------------------------------------------
    yflat = jnp.concatenate([y.reshape(e * c, d), jnp.zeros((1, d), y.dtype)], axis=0)
    out_sorted = jnp.take(yflat, slot, axis=0)              # [T*k, d]; spill→0
    inv = jnp.zeros((t * k,), jnp.int32).at[order].set(ar)
    out_entries = jnp.take(out_sorted, inv, axis=0).reshape(t, k, d)
    out = jnp.sum(out_entries * gate[..., None].astype(out_entries.dtype), axis=1)

    if not return_aux:
        return out
    # load-balancing auxiliary loss (Switch-style): E * Σ_e f_e · p_e
    me = jnp.mean(probs, axis=0)                            # mean router prob
    ce = jnp.zeros((e,), jnp.float32).at[fe].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)
    return out, aux
