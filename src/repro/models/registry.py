"""build_model(cfg) — family dispatch for the unified Model API.

Every model exposes: ``init(key)``, ``loss(params, batch)``,
``prefill(params, batch, max_len)``, ``decode_step(params, state, token, pos)``.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.encdec import EncDecLM
from repro.models.hybrid import HybridLM
from repro.models.mamba_lm import MambaLM
from repro.models.transformer import DecoderLM


def build_model(cfg: ArchConfig, backend: str = "xla", remat: bool = False):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg, backend=backend, remat=remat)
    if cfg.family == "ssm":
        return MambaLM(cfg, backend=backend, remat=remat)
    if cfg.family == "hybrid":
        return HybridLM(cfg, backend=backend, remat=remat)
    if cfg.family == "encdec":
        return EncDecLM(cfg, backend=backend, remat=remat)
    raise ValueError(f"unknown family {cfg.family!r}")
