"""Decoder-only transformer LM (dense / MoE / VLM families).

Layers are a single stacked pytree scanned with ``jax.lax.scan`` so HLO size
(and compile time) is O(1) in depth; the KV cache is threaded through the scan
as per-layer xs/ys.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.common import (alibi_slopes, embed_init, logical_constraint,
                                 norm_apply, norm_init, split_keys)
from repro.models.losses import causal_lm_loss
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init


class DecoderLM:
    """Families: dense, moe, vlm (backbone + stub patch embeddings)."""

    def __init__(self, cfg: ArchConfig, backend: str = "xla", remat: bool = False):
        self.cfg = cfg
        self.backend = backend
        self.remat = remat
        self._alibi = (jnp.asarray(alibi_slopes(cfg.num_heads))
                       if cfg.pos_emb == "alibi" else None)
        # per-layer sliding window (0 = full attention), threaded through
        # every layer scan as xs so full-attn-layer mixes stay O(1)-HLO;
        # all-zeros for windowless configs (`_layer` then keeps the static
        # no-window mask path, and the array is dead-code-eliminated)
        self._layer_window = jnp.asarray(
            [0 if i in cfg.full_attn_layers else cfg.sliding_window
             for i in range(cfg.num_layers)], jnp.int32)

    # ------------------------------------------------------------------
    def init(self, key) -> Dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        kE, kP, kL, kH, kV = split_keys(key, 5)
        p: Dict = {"embed": embed_init(kE, (cfg.vocab_size, cfg.d_model), dtype)}
        if cfg.pos_emb == "learned":
            p["pos_table"] = embed_init(kP, (cfg.max_seq_len, cfg.d_model), dtype)
        if cfg.family == "vlm":
            p["patch_proj"] = embed_init(kV, (cfg.d_model, cfg.d_model), dtype)

        def one_layer(k):
            k1, k2, k3 = split_keys(k, 3)
            lp = {"ln1": norm_init(cfg.norm, cfg.d_model, dtype),
                  "attn": attn.attn_init(k1, cfg, dtype),
                  "ln2": norm_init(cfg.norm, cfg.d_model, dtype)}
            if cfg.is_moe:
                lp["moe"] = moe_init(k2, cfg, dtype)
            else:
                lp["mlp"] = mlp_init(k2, cfg, dtype)
            return lp

        keys = split_keys(kL, cfg.num_layers)
        p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *[one_layer(k) for k in keys])
        p["final_norm"] = norm_init(cfg.norm, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(kH, (cfg.d_model, cfg.vocab_size), dtype)
        return p

    # ------------------------------------------------------------------
    def _embed(self, params, tokens, patch_embeds=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.family == "vlm":
            assert patch_embeds is not None, "vlm needs patch_embeds"
            patches = patch_embeds.astype(x.dtype) @ params["patch_proj"]
            x = jnp.concatenate([patches, x], axis=1)
        if cfg.pos_emb == "learned":
            s = x.shape[1]
            x = x + params["pos_table"][None, :s]
        return x

    def _unembed(self, params, x):
        head = (params["embed"].T if self.cfg.tie_embeddings else params["lm_head"])
        logits = x @ head
        return logical_constraint(logits, "batch", None, "vocab")

    def _layer(self, x, lp, *, mode, positions=None, kc=None, vc=None,
               kv_positions=None, pos=None, q_lens=None, window=0,
               collect_aux=False):
        cfg = self.cfg
        if cfg.sliding_window == 0:
            window = 0        # static: windowless configs keep the plain mask
        num_meta = cfg.num_meta_tokens
        x = logical_constraint(x, "batch", "seq", None)   # residual stream
        h = norm_apply(cfg.norm, x, lp["ln1"])
        rope = cfg.pos_emb == "rope"
        if mode == "prefill":
            a, k, v = attn.attention_prefill(h, lp["attn"], cfg, positions,
                                             window=window, num_meta=num_meta,
                                             rope=rope, alibi=self._alibi,
                                             backend=self.backend)
            extra = (k, v)
        elif mode == "decode_batch":
            a, kc, vc = attn.attention_decode_batch(h, lp["attn"], cfg, kc, vc,
                                                    kv_positions, pos,
                                                    q_lens=q_lens,
                                                    window=window,
                                                    num_meta=num_meta,
                                                    rope=rope,
                                                    alibi=self._alibi,
                                                    backend=self.backend)
            extra = (kc, vc)
        else:
            a, kc, vc = attn.attention_decode(h, lp["attn"], cfg, kc, vc,
                                              kv_positions, pos,
                                              window=window, num_meta=num_meta,
                                              rope=rope,
                                              alibi=self._alibi, backend=self.backend)
            extra = (kc, vc)
        x = x + a
        h = norm_apply(cfg.norm, x, lp["ln2"])
        aux = jnp.float32(0.0)
        if cfg.is_moe:
            b, s, d = h.shape
            flat = h.reshape(b * s, d)
            if collect_aux:
                # training: capacity-bounded dispatch + load-balancing aux
                out, aux = moe_apply(flat, lp["moe"], cfg, return_aux=True)
            else:
                # inference: lossless dispatch — capacity depends on the
                # pass's token count, so dropping would make a token's
                # output vary with how the scheduler packed the pass
                out = moe_apply(flat, lp["moe"], cfg, drop=False)
            out = out.reshape(b, s, d)
        else:
            out = mlp_apply(h, lp["mlp"], cfg)
        return x + out, extra, aux

    # ------------------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, batch.get("patch_embeds"))
        s_total = x.shape[1]
        positions = jnp.arange(s_total, dtype=jnp.int32)

        def body(x, xs):
            lp, w = xs
            x, _, aux = self._layer(x, lp, mode="prefill", positions=positions,
                                    window=w, collect_aux=cfg.is_moe)
            return x, aux

        if self.remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, (params["layers"], self._layer_window))
        x = norm_apply(cfg.norm, x, params["final_norm"])
        if cfg.family == "vlm":  # drop patch positions before the LM head
            x = x[:, cfg.num_patches:]
        logits = self._unembed(params, x)
        loss = causal_lm_loss(logits, batch["targets"], batch["loss_mask"])
        if cfg.is_moe:
            loss = loss + 0.01 * jnp.mean(auxs)
        return loss

    # ------------------------------------------------------------------
    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Returns (last_token_logits [B,V], decode_state, next_pos)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, batch.get("patch_embeds"))
        b, s_total, _ = x.shape
        max_len = max(max_len or s_total, s_total)  # total context incl. patches
        positions = jnp.arange(s_total, dtype=jnp.int32)

        def body(x, xs):
            lp, w = xs
            x, (k, v), _ = self._layer(x, lp, mode="prefill",
                                       positions=positions, window=w)
            return x, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"],
                                             self._layer_window))
        x = norm_apply(cfg.norm, x, params["final_norm"])
        logits = self._unembed(params, x[:, -1:, :])[:, 0]
        hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        kcache = jnp.zeros((cfg.num_layers, b, max_len, hkv, dh), ks.dtype)
        vcache = jnp.zeros_like(kcache)
        kcache = jax.lax.dynamic_update_slice_in_dim(kcache, ks, 0, axis=2)
        vcache = jax.lax.dynamic_update_slice_in_dim(vcache, vs, 0, axis=2)
        state = {"kv": {"k": kcache, "v": vcache}}
        return logits, state, jnp.int32(s_total)

    # ------------------------------------------------------------------
    # Stage-wise API for pipeline-parallel workers (DéjàVu cluster).
    # A stage owns a contiguous layer slice; stage 0 also embeds, the last
    # stage also applies the final norm + LM head.
    # ------------------------------------------------------------------

    def slice_params(self, params, lo: int, hi: int, *, first: bool, last: bool):
        sp = {"layers": jax.tree.map(lambda a: a[lo:hi], params["layers"]),
              "layer_window": self._layer_window[lo:hi]}
        if first:
            for k in ("embed", "pos_table", "patch_proj"):
                if k in params:
                    sp[k] = params[k]
        if last:
            sp["final_norm"] = params["final_norm"]
            if self.cfg.tie_embeddings:
                sp["embed"] = params["embed"]
            elif "lm_head" in params:
                sp["lm_head"] = params["lm_head"]
        return sp

    def stage_prefill(self, sp, x, *, first: bool, last: bool,
                      tokens=None, patch_embeds=None):
        """Run one stage over a full prompt.  Stage 0 passes tokens instead
        of x.  Returns (x_out_or_logits, ks, vs) with ks/vs [Lstage,B,S,..]."""
        cfg = self.cfg
        if first:
            x = self._embed(sp, tokens, patch_embeds)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)

        def body(x, xs):
            lp, w = xs
            x, (k, v), _ = self._layer(x, lp, mode="prefill",
                                       positions=positions, window=w)
            return x, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, (sp["layers"],
                                             sp["layer_window"]))
        if last:
            x = norm_apply(cfg.norm, x, sp["final_norm"])
            x = self._unembed(sp, x[:, -1:, :])[:, 0]
        return x, ks, vs

    def stage_prefill_chunk(self, sp, x, kc, vc, pos, *, first: bool,
                            last: bool, tokens=None):
        """Chunked paged prefill for one stage: a chunk of C prompt tokens at
        absolute positions pos..pos+C-1 attends causally over the cache
        prefix [0,pos) (densified pool pages) plus itself, writing its K/V
        into the cache window at `pos`.  Stage 0 passes `tokens` [B,C]; the
        last stage returns the chunk's final-token logits (only the final
        chunk's matter — they are the prefill logits).  kc/vc: [Lstage,B,S,H,D].
        """
        cfg = self.cfg
        if first:
            x = jnp.take(sp["embed"], tokens, axis=0)
            if cfg.pos_emb == "learned":
                x = x + jax.lax.dynamic_slice_in_dim(
                    sp["pos_table"], pos, tokens.shape[1], axis=0)[None]
        c = x.shape[1]
        s_cache = kc.shape[2]
        kv_positions = jnp.arange(s_cache, dtype=jnp.int32)
        kv_positions = jnp.where(kv_positions < pos + c, kv_positions, -1)

        def body(x, xs):
            lp, k1, v1, w = xs
            x, (k1, v1), _ = self._layer(x, lp, mode="decode", kc=k1, vc=v1,
                                         kv_positions=kv_positions, pos=pos,
                                         window=w)
            return x, (k1, v1)

        x, (kc, vc) = jax.lax.scan(body, x, (sp["layers"], kc, vc,
                                             sp["layer_window"]))
        if last:
            x = norm_apply(cfg.norm, x, sp["final_norm"])
            x = self._unembed(sp, x[:, -1:, :])[:, 0]
        return x, kc, vc

    def stage_decode_batch(self, sp, x, kc, vc, pos, *, first: bool,
                           last: bool, token=None):
        """Fused-round decode for one stage: B sequences each advance ONE
        step in a single pipeline pass, sequence b's new token sitting at its
        OWN position ``pos[b]`` (ragged lengths — vs `stage_decode`'s shared
        scalar).  kc/vc: [Lstage,B,S,H,D] with S a common pad; pos: [B]."""
        cfg = self.cfg
        if first:
            x = jnp.take(sp["embed"], token[:, None], axis=0)
            if cfg.pos_emb == "learned":
                x = x + jnp.take(sp["pos_table"], pos, axis=0)[:, None]
        s_cache = kc.shape[2]
        slots = jnp.arange(s_cache, dtype=jnp.int32)[None, :]
        kv_positions = jnp.where(slots <= pos[:, None], slots, -1)   # [B,S]

        def body(x, xs):
            lp, k1, v1, w = xs
            x, (k1, v1), _ = self._layer(x, lp, mode="decode_batch", kc=k1,
                                         vc=v1, kv_positions=kv_positions,
                                         pos=pos, window=w)
            return x, (k1, v1)

        x, (kc, vc) = jax.lax.scan(body, x, (sp["layers"], kc, vc,
                                             sp["layer_window"]))
        if last:
            x = norm_apply(cfg.norm, x, sp["final_norm"])
            x = self._unembed(sp, x)[:, 0]
        return x, kc, vc

    def stage_prefill_chunk_batch(self, sp, x, kc, vc, pos, q_lens, *,
                                  first: bool, last: bool, tokens=None):
        """Fused chunk-set pass: one prefill chunk of EACH of B in-flight
        sequences runs in a single pipeline pass.  Sequence b's chunk holds
        ``q_lens[b]`` valid tokens at absolute positions ``pos[b] ..
        pos[b]+q_lens[b]-1`` (rows past q_lens[b] are padding); each chunk
        attends causally over its own cache prefix [0, pos[b]) plus itself.
        Stage 0 passes `tokens` [B,Cmax]; the last stage returns each chunk's
        final-valid-token logits [B,V] (only sequences whose prefill just
        completed read theirs).  kc/vc: [Lstage,B,S,H,D]; pos/q_lens: [B]."""
        cfg = self.cfg
        if first:
            x = jnp.take(sp["embed"], tokens, axis=0)
            if cfg.pos_emb == "learned":
                c = tokens.shape[1]
                posm = pos[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
                x = x + jnp.take(sp["pos_table"],
                                 jnp.clip(posm, 0, sp["pos_table"].shape[0] - 1),
                                 axis=0)
        c = x.shape[1]
        s_cache = kc.shape[2]
        slots = jnp.arange(s_cache, dtype=jnp.int32)[None, :]
        kv_positions = jnp.where(slots < (pos + q_lens)[:, None], slots, -1)

        def body(x, xs):
            lp, k1, v1, w = xs
            x, (k1, v1), _ = self._layer(x, lp, mode="decode_batch", kc=k1,
                                         vc=v1, kv_positions=kv_positions,
                                         pos=pos, q_lens=q_lens, window=w)
            return x, (k1, v1)

        x, (kc, vc) = jax.lax.scan(body, x, (sp["layers"], kc, vc,
                                             sp["layer_window"]))
        if last:
            x = norm_apply(cfg.norm, x, sp["final_norm"])
            # per-sequence final valid token (ragged chunks): row q_lens[b]-1
            sel = (jnp.arange(c, dtype=jnp.int32)[None, :]
                   == (q_lens - 1)[:, None]).astype(x.dtype)       # [B,C]
            x = jnp.einsum("bc,bcd->bd", sel, x)
            x = self._unembed(sp, x[:, None])[:, 0]
        return x, kc, vc

    def stage_decode(self, sp, x, kc, vc, pos, *, first: bool, last: bool,
                     token=None):
        """One decode step for one stage.  kc/vc: [Lstage,B,S,H,D]."""
        cfg = self.cfg
        if first:
            x = jnp.take(sp["embed"], token[:, None], axis=0)
            if cfg.pos_emb == "learned":
                x = x + jax.lax.dynamic_slice_in_dim(sp["pos_table"], pos, 1, axis=0)[None]
        s_cache = kc.shape[2]
        kv_positions = jnp.arange(s_cache, dtype=jnp.int32)
        kv_positions = jnp.where(kv_positions <= pos, kv_positions, -1)

        def body(x, xs):
            lp, k1, v1, w = xs
            x, (k1, v1), _ = self._layer(x, lp, mode="decode", kc=k1, vc=v1,
                                         kv_positions=kv_positions, pos=pos,
                                         window=w)
            return x, (k1, v1)

        x, (kc, vc) = jax.lax.scan(body, x, (sp["layers"], kc, vc,
                                             sp["layer_window"]))
        if last:
            x = norm_apply(cfg.norm, x, sp["final_norm"])
            x = self._unembed(sp, x)[:, 0]
        return x, kc, vc

    # ------------------------------------------------------------------
    def decode_step(self, params, state, token, pos):
        """token: [B] int32; pos: scalar int32 (position of the new token).

        Returns (logits [B,V], new_state)."""
        cfg = self.cfg
        x = jnp.take(params["embed"], token[:, None], axis=0)
        if cfg.pos_emb == "learned":
            x = x + jax.lax.dynamic_slice_in_dim(params["pos_table"], pos, 1, axis=0)[None]
        s_cache = state["kv"]["k"].shape[2]
        kv_positions = jnp.arange(s_cache, dtype=jnp.int32)
        kv_positions = jnp.where(kv_positions <= pos, kv_positions, -1)

        def body(x, xs):
            lp, kc, vc, w = xs
            x, (kc, vc), _ = self._layer(x, lp, mode="decode", kc=kc, vc=vc,
                                         kv_positions=kv_positions, pos=pos,
                                         window=w)
            return x, (kc, vc)

        x, (kcs, vcs) = jax.lax.scan(body, x, (params["layers"],
                                               state["kv"]["k"],
                                               state["kv"]["v"],
                                               self._layer_window))
        x = norm_apply(cfg.norm, x, params["final_norm"])
        logits = self._unembed(params, x)[:, 0]
        return logits, {"kv": {"k": kcs, "v": vcs}}
