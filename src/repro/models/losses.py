"""Loss functions (memory-aware: never materializes f32 [B,S,V])."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_lm_loss(logits, targets, loss_mask):
    """logits: [B,S,V] (bf16 ok); targets: [B,S] int32; loss_mask: [B,S].

    CE = logsumexp(logits) − logits[target]; both are fused reductions/gathers
    so the f32 blow-up of the full logits tensor is never materialized.
    """
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)   # [B,S]
    tgt = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                              axis=-1)[..., 0].astype(jnp.float32)
    ce = lse - tgt
    mask = loss_mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(ce * mask) / denom
