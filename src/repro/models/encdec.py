"""Encoder-decoder backbone (SeamlessM4T family).

The modality frontend is a STUB: callers supply precomputed frame embeddings
``src_embeds [B, S_src, d_model]``.  The encoder is stateless (bidirectional);
the decoder carries a causal self-attention KV cache plus per-request
cross-attention K/V computed once from the encoder output — both belong to
the DéjàVu decode state (the cross-KV streams with the prompt cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.common import embed_init, norm_apply, norm_init, split_keys
from repro.models.losses import causal_lm_loss
from repro.models.mlp import mlp_apply, mlp_init


class EncDecLM:
    def __init__(self, cfg: ArchConfig, backend: str = "xla", remat: bool = False):
        self.cfg = cfg
        self.backend = backend
        self.remat = remat

    # ------------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        kE, kSP, kDP, kEL, kDL, kH = split_keys(key, 6)
        p = {
            "embed": embed_init(kE, (cfg.vocab_size, cfg.d_model), dtype),
            "src_pos": embed_init(kSP, (cfg.max_source_len, cfg.d_model), dtype),
            "pos_table": embed_init(kDP, (cfg.max_seq_len, cfg.d_model), dtype),
        }

        def enc_layer(k):
            k1, k2 = split_keys(k, 2)
            return {"ln1": norm_init(cfg.norm, cfg.d_model, dtype),
                    "attn": attn.attn_init(k1, cfg, dtype),
                    "ln2": norm_init(cfg.norm, cfg.d_model, dtype),
                    "mlp": mlp_init(k2, cfg, dtype)}

        def dec_layer(k):
            k1, k2, k3 = split_keys(k, 3)
            return {"ln1": norm_init(cfg.norm, cfg.d_model, dtype),
                    "attn": attn.attn_init(k1, cfg, dtype),
                    "lnx": norm_init(cfg.norm, cfg.d_model, dtype),
                    "cross": attn.attn_init(k2, cfg, dtype),
                    "ln2": norm_init(cfg.norm, cfg.d_model, dtype),
                    "mlp": mlp_init(k3, cfg, dtype)}

        p["enc_layers"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[enc_layer(k) for k in split_keys(kEL, cfg.num_encoder_layers)])
        p["dec_layers"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[dec_layer(k) for k in split_keys(kDL, cfg.num_layers)])
        p["enc_final"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["final_norm"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["lm_head"] = embed_init(kH, (cfg.d_model, cfg.vocab_size), dtype)
        return p

    # ------------------------------------------------------------------
    def encode(self, params, src_embeds):
        cfg = self.cfg
        s = src_embeds.shape[1]
        x = src_embeds.astype(jnp.dtype(cfg.dtype)) + params["src_pos"][None, :s]
        positions = jnp.arange(s, dtype=jnp.int32)

        def body(x, lp):
            h = norm_apply(cfg.norm, x, lp["ln1"])
            q, k, v = attn.qkv_proj(h, lp["attn"], cfg)
            o = attn.attend(q, k, v, mask=None, backend=self.backend)  # bidirectional
            x = x + attn.out_proj(o, lp["attn"])
            x = x + mlp_apply(norm_apply(cfg.norm, x, lp["ln2"]), lp["mlp"], cfg)
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return norm_apply(cfg.norm, x, params["enc_final"])

    # ------------------------------------------------------------------
    def _decoder(self, params, tokens, enc_out, collect: bool):
        cfg = self.cfg
        s = tokens.shape[1]
        x = jnp.take(params["embed"], tokens, axis=0) + params["pos_table"][None, :s]
        positions = jnp.arange(s, dtype=jnp.int32)

        def body(x, lp):
            h = norm_apply(cfg.norm, x, lp["ln1"])
            a, k, v = attn.attention_prefill(h, lp["attn"], cfg, positions,
                                             rope=False, backend=self.backend)
            x = x + a
            h = norm_apply(cfg.norm, x, lp["lnx"])
            ck, cv = attn.cross_kv(enc_out, lp["cross"], cfg)
            x = x + attn.cross_attention(h, lp["cross"], cfg, ck, cv, backend=self.backend)
            x = x + mlp_apply(norm_apply(cfg.norm, x, lp["ln2"]), lp["mlp"], cfg)
            return x, (k, v, ck, cv) if collect else None

        if self.remat and not collect:
            body = jax.checkpoint(body)
        x, ys = jax.lax.scan(body, x, params["dec_layers"])
        x = norm_apply(cfg.norm, x, params["final_norm"])
        return x, ys

    # ------------------------------------------------------------------
    def loss(self, params, batch):
        enc_out = self.encode(params, batch["src_embeds"])
        x, _ = self._decoder(params, batch["tokens"], enc_out, collect=False)
        logits = x @ params["lm_head"]
        return causal_lm_loss(logits, batch["targets"], batch["loss_mask"])

    def prefill(self, params, batch, max_len=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        enc_out = self.encode(params, batch["src_embeds"])
        x, (ks, vs, cks, cvs) = self._decoder(params, tokens, enc_out, collect=True)
        logits = (x[:, -1:, :] @ params["lm_head"])[:, 0]
        max_len = max_len or s
        hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        kc = jnp.zeros((cfg.num_layers, b, max_len, hkv, dh), ks.dtype)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, ks, 0, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vs, 0, axis=2)
        state = {"kv": {"k": kc, "v": vc}, "cross": {"k": cks, "v": cvs}}
        return logits, state, jnp.int32(s)

    def decode_step(self, params, state, token, pos):
        cfg = self.cfg
        x = jnp.take(params["embed"], token[:, None], axis=0)
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_table"], pos, 1, axis=0)[None]
        s_cache = state["kv"]["k"].shape[2]
        kv_positions = jnp.arange(s_cache, dtype=jnp.int32)
        kv_positions = jnp.where(kv_positions <= pos, kv_positions, -1)

        def body(x, xs):
            lp, kc, vc, ck, cv = xs
            h = norm_apply(cfg.norm, x, lp["ln1"])
            a, kc, vc = attn.attention_decode(h, lp["attn"], cfg, kc, vc,
                                              kv_positions, pos, rope=False,
                                              backend=self.backend)
            x = x + a
            h = norm_apply(cfg.norm, x, lp["lnx"])
            x = x + attn.cross_attention(h, lp["cross"], cfg, ck, cv, backend=self.backend)
            x = x + mlp_apply(norm_apply(cfg.norm, x, lp["ln2"]), lp["mlp"], cfg)
            return x, (kc, vc)

        x, (kcs, vcs) = jax.lax.scan(
            body, x, (params["dec_layers"], state["kv"]["k"], state["kv"]["v"],
                      state["cross"]["k"], state["cross"]["v"]))
        x = norm_apply(cfg.norm, x, params["final_norm"])
        logits = (x @ params["lm_head"])[:, 0]
        return logits, {"kv": {"k": kcs, "v": vcs}, "cross": state["cross"]}
