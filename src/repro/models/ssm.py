"""Mamba-2 (SSD — state-space duality) block.

Prefill/training use the chunked SSD algorithm (intra-chunk attention-like
matmuls + inter-chunk state recurrence — MXU-friendly); decode is the O(1)
recurrent update.  The recurrent state (``ssd`` [nh,hd,N] f32 + ``conv``
[K-1,conv_dim]) is this family's "decode state" for DéjàVu streaming.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm, split_keys

DEFAULT_CHUNK = 128


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def ssm_init(key, cfg, dtype):
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh, kconv = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv
    conv_dim = di + 2 * g * n
    kin, kout, kconv_w, ka, kdt = split_keys(key, 5)
    return {
        "w_in": dense_init(kin, (d, 2 * di + 2 * g * n + nh), dtype),
        "w_out": dense_init(kout, (di, d), dtype),
        "conv_w": dense_init(kconv_w, (kconv, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
    }


def _split_in(h, cfg):
    di, g, n, nh = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = h[..., :di]
    xbc = h[..., di: 2 * di + 2 * g * n]
    dt = h[..., 2 * di + 2 * g * n:]
    return z, xbc, dt


def _proj_in_parts(x, p, cfg):
    """Input projection as per-segment matmuls over SLICED (replicated)
    weight columns — mathematically identical to one big matmul, but each
    segment's output dim shards cleanly over `model` (z/x: d_inner, B/C:
    groups·state), which is what makes batch=1 long-context decode scale
    (see DESIGN.md / §Perf mamba2 hillclimb).  Returns (z, x, b, c, dt).

    The split exists FOR sharding: when no `d_inner` rule is active the
    single fused matmul is used instead (the 5-way weight slicing costs
    extra copies with nothing to pay for them — measured in §Perf)."""
    from repro.models import common
    from repro.models.common import logical_constraint
    di, g, n = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    gn = g * n
    w = p["w_in"]
    nd = x.ndim
    rules = common._LOGICAL_RULES or {}
    if rules.get("d_inner") is None:
        h = x @ w
        return (h[..., :di], h[..., di: 2 * di],
                h[..., 2 * di: 2 * di + gn],
                h[..., 2 * di + gn: 2 * di + 2 * gn],
                h[..., 2 * di + 2 * gn:])
    pre = [None] * (nd - 1)
    z = logical_constraint(x @ w[..., :di], *pre, "d_inner")
    xp = logical_constraint(x @ w[..., di: 2 * di], *pre, "d_inner")
    bp = logical_constraint(x @ w[..., 2 * di: 2 * di + gn], *pre, "ssm_gn")
    cp = logical_constraint(x @ w[..., 2 * di + gn: 2 * di + 2 * gn], *pre, "ssm_gn")
    dt = x @ w[..., 2 * di + 2 * gn:]
    return z, xp, bp, cp, dt


def _conv_slices(cfg):
    """(x, b, c) channel slices of the concatenated conv buffers."""
    di, gn = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state
    return slice(0, di), slice(di, di + gn), slice(di + gn, di + 2 * gn)


def _split_xbc(xbc, cfg):
    di, g, n = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    x = xbc[..., :di]
    bmat = xbc[..., di: di + g * n]
    cmat = xbc[..., di + g * n:]
    return x, bmat, cmat


# ---------------------------------------------------------------------------
# Chunked SSD scan (prefill / training)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, a_neg, bmat, cmat, chunk: int = DEFAULT_CHUNK, h0=None):
    """Chunked SSD.  x: [B,S,nh,hd]; dt: [B,S,nh] (post-softplus);
    a_neg: [nh] (negative); bmat/cmat: [B,S,G,N].  Returns (y, h_final).
    All state math in f32.
    """
    b, s, nh, hd = x.shape
    g, n = bmat.shape[-2], bmat.shape[-1]
    rep = nh // g
    a_neg = a_neg.astype(jnp.float32)   # keep the scan carry f32 under x64
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    xs = x.reshape(b, nc, chunk, nh, hd).astype(jnp.float32)
    dts = dt.reshape(b, nc, chunk, nh).astype(jnp.float32)
    bs = bmat.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    cs = cmat.reshape(b, nc, chunk, g, n).astype(jnp.float32)

    da = dts * a_neg                                   # [b,nc,q,nh]
    da_cum = jnp.cumsum(da, axis=2)                    # inclusive
    # intra-chunk decay L[i,j,h] = exp(da_cum[i] - da_cum[j]), i >= j.
    # Mask BEFORE exp: masked (i<j) entries have positive li that overflows
    # exp, and where(mask, inf, 0) poisons gradients with inf·0 = NaN.
    li = da_cum[:, :, :, None, :] - da_cum[:, :, None, :, :]   # [b,nc,i,j,h]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    li = jnp.where(tri[None, None, :, :, None], li, -1e30)
    lmat = jnp.exp(li)

    cb = jnp.einsum("bcign,bcjgn->bcgij", cs, bs)      # [b,nc,g,i,j]
    cb_h = jnp.repeat(cb, rep, axis=2)                 # [b,nc,nh,i,j]
    scores = cb_h * jnp.moveaxis(lmat, -1, 2)          # [b,nc,h,i,j]
    y_diag = jnp.einsum("bchij,bcjh,bcjhd->bcihd", scores, dts, xs)

    # chunk state contributions S_c = Σ_j exp(da_last - da_j)·dt_j·B_j⊗x_j
    decay_states = jnp.exp(da_cum[:, :, -1:, :] - da_cum)      # [b,nc,j,h]
    b_h = jnp.repeat(bs, rep, axis=3)                  # [b,nc,j,nh,n]
    states = jnp.einsum("bcjhn,bcjh,bcjh,bcjhd->bchdn",
                        b_h, decay_states, dts, xs)    # [b,nc,nh,hd,n]
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])         # [b,nc,nh]

    hinit = jnp.zeros((b, nh, hd, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def body(h, inputs):
        s_c, dec = inputs                              # [b,nh,hd,n], [b,nh]
        h_out = h * dec[:, :, None, None] + s_c
        return h_out, h                                # emit state ENTERING chunk

    hfin, h_in = jax.lax.scan(body, hinit,
                              (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                    # [b,nc,nh,hd,n]

    c_h = jnp.repeat(cs, rep, axis=3)                  # [b,nc,i,nh,n]
    y_off = jnp.einsum("bcihn,bchdn,bcih->bcihd", c_h, h_in, jnp.exp(da_cum))
    y = (y_diag + y_off).reshape(b, sp, nh, hd)[:, :s]
    return y.astype(x.dtype), hfin


def ssd_decode_step(x, dt, a_neg, bmat, cmat, h):
    """One-token recurrent update.  x: [B,nh,hd]; dt: [B,nh]; b/c: [B,G,N];
    h: [B,nh,hd,N] f32.  Returns (y [B,nh,hd], h')."""
    from repro.models.common import logical_constraint
    nh = x.shape[1]
    g = bmat.shape[1]
    rep = nh // g
    xf = logical_constraint(x.astype(jnp.float32), None, "ssm_heads", None)
    da = jnp.exp(dt.astype(jnp.float32) * a_neg.astype(jnp.float32))   # [B,nh]
    b_h = jnp.repeat(bmat.astype(jnp.float32), rep, axis=1)    # [B,nh,N]
    c_h = jnp.repeat(cmat.astype(jnp.float32), rep, axis=1)
    b_h = logical_constraint(b_h, None, "ssm_heads", None)
    c_h = logical_constraint(c_h, None, "ssm_heads", None)
    h_new = h * da[:, :, None, None] + (dt.astype(jnp.float32)[:, :, None, None]
                                        * xf[:, :, :, None] * b_h[:, :, None, :])
    h_new = logical_constraint(h_new, None, "ssm_heads", None, None)
    y = jnp.einsum("bhdn,bhn->bhd", h_new, c_h)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Full Mamba-2 block (conv + gate + SSD + norm + out-proj)
# ---------------------------------------------------------------------------

def _causal_conv(xbc, w, bias):
    """Depthwise causal conv.  xbc: [B,S,C]; w: [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i] for i in range(k))
    return out + bias


def ssm_prefill(x, p, cfg, h0=None, conv0=None, chunk: int = DEFAULT_CHUNK, backend: str = "xla"):
    """x: [B,S,d] -> (out [B,S,d], ssd_state [B,nh,hd,N], conv_state [B,K-1,conv_dim])."""
    from repro.models.common import logical_constraint
    z, xp, bp, cp, dt = _proj_in_parts(x, p, cfg)
    sx, sb, sc = _conv_slices(cfg)
    km1 = cfg.ssm_conv - 1

    def conv_part(part, ch_slice, ctx):
        w = p["conv_w"][:, ch_slice]
        bias = p["conv_b"][ch_slice]
        if ctx is not None:
            full = jnp.concatenate([ctx.astype(part.dtype), part], axis=1)
            return _causal_conv(full, w, bias)[:, ctx.shape[1]:]
        return _causal_conv(part, w, bias)

    ctx_x = conv0[:, :, sx] if conv0 is not None else None
    ctx_b = conv0[:, :, sb] if conv0 is not None else None
    ctx_c = conv0[:, :, sc] if conv0 is not None else None
    xin = jax.nn.silu(conv_part(xp, sx, ctx_x))
    bmat = jax.nn.silu(conv_part(bp, sb, ctx_b))
    cmat = jax.nn.silu(conv_part(cp, sc, ctx_c))

    def tail(part, ctx):
        seq = jnp.concatenate([ctx, part], axis=1) if ctx is not None else \
            jnp.pad(part, ((0, 0), (km1, 0), (0, 0)))
        return seq[:, -km1:]

    conv_state = jnp.concatenate(
        [tail(xp, ctx_x), tail(bp, ctx_b), tail(cp, ctx_c)], axis=2)

    b, s, _ = x.shape
    xh = xin.reshape(b, s, cfg.ssm_nheads, cfg.ssm_head_dim)
    bm = bmat.reshape(b, s, cfg.ssm_ngroups, cfg.ssm_state)
    cm = cmat.reshape(b, s, cfg.ssm_ngroups, cfg.ssm_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["A_log"])
    if backend == "pallas":
        from repro.kernels import ops as kops
        y, hfin = kops.ssd_auto(xh, dtv, a_neg, bm, cm, chunk=chunk, h0=h0)
    else:
        y, hfin = ssd_chunked(xh, dtv, a_neg, bm, cm, chunk=chunk, h0=h0)
    y = y + (p["D"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, s, cfg.d_inner)
    y = logical_constraint(y, None, None, "d_inner")
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["w_out"], hfin, conv_state.astype(x.dtype)


def ssm_decode(x, p, cfg, ssd_state, conv_state):
    """x: [B,1,d] -> (out [B,1,d], ssd_state', conv_state')."""
    from repro.models.common import logical_constraint
    b = x.shape[0]
    z, xp, bp, cp, dt = _proj_in_parts(x[:, 0], p, cfg)
    sx, sb, sc = _conv_slices(cfg)

    def conv_step(part, ch_slice, ctx):
        w = p["conv_w"][:, ch_slice]
        bias = p["conv_b"][ch_slice]
        win = jnp.concatenate([ctx.astype(part.dtype), part[:, None, :]], axis=1)
        out = jnp.einsum("bkc,kc->bc", win, w) + bias
        return jax.nn.silu(out), win[:, 1:]

    xin, wx = conv_step(xp, sx, conv_state[:, :, sx])
    bmat, wb = conv_step(bp, sb, conv_state[:, :, sb])
    cmat, wc = conv_step(cp, sc, conv_state[:, :, sc])
    new_conv = jnp.concatenate([wx, wb, wc], axis=2).astype(conv_state.dtype)

    xh = xin.reshape(b, cfg.ssm_nheads, cfg.ssm_head_dim)
    bm = bmat.reshape(b, cfg.ssm_ngroups, cfg.ssm_state)
    cm = cmat.reshape(b, cfg.ssm_ngroups, cfg.ssm_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["A_log"])
    y, h_new = ssd_decode_step(xh, dtv, a_neg, bm, cm, ssd_state)
    y = y + (p["D"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, cfg.d_inner)
    y = logical_constraint(y, None, "d_inner")
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return (y @ p["w_out"])[:, None, :], h_new, new_conv
