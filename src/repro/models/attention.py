"""GQA attention: projections, masking variants, XLA and Pallas backends.

Masking is position-based so the same code serves full-causal, sliding-window
(+ always-visible meta tokens, Hymba-style), cross-attention (no mask), and
single-token decode against a partially-filled cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, logical_constraint, split_keys

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    kq, kk, kv, ko = split_keys(key, 4)
    return {
        "wq": dense_init(kq, (d, qd), dtype),
        "wk": dense_init(kk, (d, kvd), dtype),
        "wv": dense_init(kv, (d, kvd), dtype),
        "wo": dense_init(ko, (qd, d), dtype),
    }


def qkv_proj(x, p, cfg):
    """x: [B,S,d] -> q [B,S,Hq,Dh], k/v [B,S,Hkv,Dh]."""
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, dh)
    k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, dh)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, dh)
    q = logical_constraint(q, "batch", None, "heads", None)
    k = logical_constraint(k, "batch", None, "kv_heads", None)
    v = logical_constraint(v, "batch", None, "kv_heads", None)
    return q, k, v


def out_proj(o, p):
    b, s, h, dh = o.shape
    return o.reshape(b, s, h * dh) @ p["wo"]


# ---------------------------------------------------------------------------
# Mask construction (position-based)
# ---------------------------------------------------------------------------

def build_mask(q_pos, kv_pos, *, causal: bool, window: int = 0, num_meta: int = 0):
    """Boolean mask [.., Sq, Skv]; True = attend.

    q_pos: [Sq] or [B,Sq]; kv_pos: [Skv] or [B,Skv] int32 (−1 = empty slot).
    Meta tokens occupy positions [0, num_meta) and are always visible.
    Window (if >0) permits kv within the last `window` positions of q.
    `window` may be a traced int32 scalar (the per-layer window threaded
    through a `lax.scan` for full-attn-layer mixes); a traced 0 disables the
    window at runtime, a static 0 skips the branch entirely.
    """
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = kv_pos[..., None, :].astype(jnp.int32)
    mask = kp >= 0
    if causal:
        mask &= kp <= qp
    if not (isinstance(window, int) and window == 0):
        w = jnp.asarray(window, jnp.int32)
        in_window = kp > qp - w
        is_meta = kp < num_meta
        mask &= jnp.where(w > 0, in_window | is_meta, True)
    return mask


# ---------------------------------------------------------------------------
# Core attention (XLA backend; GSPMD-shardable)
# ---------------------------------------------------------------------------

def attend(q, k, v, mask=None, bias=None, backend: str = "xla"):
    """q: [B,Sq,Hq,Dh], k/v: [B,Skv,Hkv,Dh], mask: [.., Sq,Skv] bool.

    GQA: Hq = G * Hkv.  Softmax in f32.  bias: [Hq,Sq,Skv] (shared) or
    [B,Hq,Sq,Skv] (per-sequence, the fused batched round) f32 additive
    (e.g. ALiBi), added to scores before masking.
    """
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.attention_auto(q, k, v, mask=mask, bias=bias)
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if bias is not None:
        if bias.ndim == 4:
            scores = scores + bias.reshape(b, hkv, g, *bias.shape[2:])
        else:
            scores = scores + bias.reshape(hkv, g, *bias.shape[1:])[None]
    if mask is not None:
        m = mask[..., None, None, :, :] if mask.ndim == 2 else mask[:, None, None]
        scores = jnp.where(m, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, dh)


def alibi_bias(slopes, q_pos, kv_pos):
    """ALiBi additive bias from absolute positions.

    q_pos [Sq], kv_pos [Skv] -> [Hq,Sq,Skv]; batched (per-sequence
    positions, the fused round) q_pos [B,Sq], kv_pos [B,Skv]
    -> [B,Hq,Sq,Skv].  Same formula either way."""
    dist = (q_pos[..., :, None] - kv_pos[..., None, :]).astype(jnp.float32)
    dist = jnp.maximum(dist, 0.0)
    if dist.ndim == 2:
        return -slopes[:, None, None] * dist
    return -slopes[None, :, None, None] * dist[:, None]


def attend_blocked(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                   window: int = 0, num_meta: int = 0, alibi=None,
                   block_q: int = 512, block_k: int = 1024):
    """Flash-style blocked attention in pure XLA (hillclimb optimization).

    Never materializes the [Sq,Skv] score matrix: a `lax.map` over Q blocks
    runs an online-softmax `lax.scan` over KV blocks with a small
    (bq-sized) carry, cutting HBM traffic from O(S²) to O(S·d) — the same
    schedule the Pallas flash kernel executes on TPU, expressed so GSPMD can
    shard it (batch over data, heads over model).
    """
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pq, pk = (-sq) % bq, (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    qpos = jnp.pad(q_pos, (0, pq), constant_values=-1) if pq else q_pos
    kpos = jnp.pad(kv_pos, (0, pk), constant_values=-1) if pk else kv_pos
    nq, nk = (sq + pq) // bq, (skv + pk) // bk
    scale = dh ** -0.5
    kb = kp.reshape(b, nk, bk, hkv, dh)
    vb = vp.reshape(b, nk, bk, hkv, dh)
    kposb = kpos.reshape(nk, bk)

    def one_q_block(args):
        qblk, qposb = args                          # [b,bq,hq,dh], [bq]
        qg = qblk.reshape(b, bq, hkv, g, dh).astype(jnp.float32) * scale

        def kv_step(carry, xs):
            m, l, acc = carry
            kblk, vblk, kpb = xs                    # [b,bk,hkv,dh], [bk]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kblk.astype(jnp.float32))
            if alibi is not None:
                dist = (qposb[:, None] - kpb[None, :]).astype(jnp.float32)
                bias = -alibi.reshape(hkv, g)[:, :, None, None] * \
                    jnp.maximum(dist, 0.0)[None, None]
                s = s + bias
            valid = kpb >= 0
            if causal:
                valid = valid[None, :] & (kpb[None, :] <= qposb[:, None])
            else:
                valid = jnp.broadcast_to(valid[None, :], (bq, bk))
            if not (isinstance(window, int) and window == 0):
                w = jnp.asarray(window, jnp.int32)
                in_w = kpb[None, :] > qposb[:, None] - w
                valid = valid & jnp.where(w > 0,
                                          in_w | (kpb < num_meta)[None, :],
                                          True)
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, g, bq), jnp.float32),
                jnp.zeros((b, hkv, g, bq, dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kposb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]    # [b,hkv,g,bq,dh]
        return jnp.moveaxis(out, 3, 1).reshape(b, bq, hq, dh).astype(q.dtype)

    qblocks = jnp.moveaxis(qp.reshape(b, nq, bq, hq, dh), 1, 0)
    out = jax.lax.map(one_q_block, (qblocks, qpos.reshape(nq, bq)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq + pq, hq, dh)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# High-level ops used by the models
# ---------------------------------------------------------------------------

def attention_prefill(x, p, cfg, positions, *, window: int = 0, num_meta: int = 0,
                      rope: bool = True, alibi=None, backend: str = "xla"):
    """Causal self-attention over a full prompt.  Returns (out, k, v)."""
    q, k, v = qkv_proj(x, p, cfg)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if backend == "blocked":
        o = attend_blocked(q, k, v, positions, positions, causal=True,
                           window=window, num_meta=num_meta, alibi=alibi)
        return out_proj(o, p), k, v
    mask = build_mask(positions, positions, causal=True, window=window, num_meta=num_meta)
    bias = alibi_bias(alibi, positions, positions) if alibi is not None else None
    o = attend(q, k, v, mask=mask, bias=bias, backend=backend)
    return out_proj(o, p), k, v


def attention_decode(x, p, cfg, k_cache, v_cache, kv_positions, pos, *,
                     window: int = 0, num_meta: int = 0, rope: bool = True,
                     alibi=None, write_index=None, backend: str = "xla"):
    """Decode / chunked-prefill attention against a partially-filled cache.

    x: [B,C,d] — C=1 is the classic one-token decode; C>1 is a chunked
    prefill step whose queries sit at absolute positions pos..pos+C-1 and
    attend causally over the cache prefix plus themselves (the paged
    `paged_prefill_attention` kernel computes the same thing over block
    tables).  cache: [B,S,Hkv,Dh]; pos: scalar int32 position of x[:,0].

    write_index: where to write the chunk's K/V (defaults to pos;
    ring-buffer caches pass their slot).  Returns (out, k_cache, v_cache).
    """
    c = x.shape[1]
    q, k_new, v_new = qkv_proj(x, p, cfg)
    posv = pos + jnp.arange(c, dtype=jnp.int32)
    if rope:
        q = apply_rope(q, posv[None, :], cfg.rope_theta)
        k_new = apply_rope(k_new, posv[None, :], cfg.rope_theta)
    wi = pos if write_index is None else write_index
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), wi, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), wi, axis=1)
    q_pos = posv
    if backend == "blocked":
        o = attend_blocked(q, k_cache, v_cache, q_pos, kv_positions,
                           causal=True, window=window, num_meta=num_meta,
                           alibi=alibi)
        return out_proj(o, p), k_cache, v_cache
    mask = build_mask(q_pos, kv_positions, causal=True, window=window, num_meta=num_meta)
    bias = alibi_bias(alibi, q_pos, jnp.maximum(kv_positions, 0)) if alibi is not None else None
    if backend == "pallas" and c == 1 and bias is None:
        from repro.kernels import ops as kops
        o = kops.decode_attention_auto(q, k_cache, v_cache, mask)
    else:
        o = attend(q, k_cache, v_cache, mask=mask, bias=bias)
    return out_proj(o, p), k_cache, v_cache


def attention_decode_batch(x, p, cfg, k_cache, v_cache, kv_positions, pos,
                           q_lens=None, *, window: int = 0, num_meta: int = 0,
                           rope: bool = True, alibi=None,
                           backend: str = "xla"):
    """Fused-round decode / chunk-pack attention: B sequences advance in ONE
    pass at per-sequence positions (vs `attention_decode`'s shared scalar
    `pos`).

    x: [B,C,d] — C=1 decodes every sequence one step; C>1 packs one prefill
    chunk per sequence, sequence b's chunk sitting at absolute positions
    ``pos[b] .. pos[b]+q_lens[b]-1`` (rows past ``q_lens[b]`` are don't-care
    padding for ragged chunk sets).  k/v_cache: [B,S,Hkv,Dh] (each sequence's
    pool pages densified and padded to a common S); kv_positions: [B,S] int32
    with −1 marking slots past each sequence's own live length; pos: [B]
    int32.  The batched mask/bias carry the same attention variants the
    per-sequence path does — sliding window (+ meta attention-sink tokens;
    `window` may be a traced per-layer scalar) and ALiBi — so the cluster's
    `fused_ok` gate only excludes families with state the mask cannot
    express (ssm/hybrid/encdec recurrence, vlm patch slots).  Returns
    (out, k_cache, v_cache).
    """
    b, c, _ = x.shape
    q, k_new, v_new = qkv_proj(x, p, cfg)
    posv = pos[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]     # [B,C]
    lens = (jnp.full((b,), c, jnp.int32) if q_lens is None
            else jnp.asarray(q_lens, jnp.int32))
    if rope:
        q = apply_rope(q, posv, cfg.rope_theta)
        k_new = apply_rope(k_new, posv, cfg.rope_theta)
    # scatter each sequence's new K/V window into its own cache rows at
    # pos[b]: O(C) work per sequence (vs an O(S) full-cache select).  Ragged
    # chunk tails (rows >= len_b) blend back to the original cache values so
    # padding rows never land in the cache; when a short final chunk's
    # padded window would overrun the cache end (pos[b] + C > S), the slice
    # start backs up and the valid rows shift within it.
    def _scatter(cache, new):
        def one(cb, nb, p, ln):
            pe = jnp.minimum(p, cb.shape[0] - c)
            idx = jnp.arange(c, dtype=jnp.int32) - (p - pe)
            keep = ((idx >= 0) & (idx < ln))[:, None, None]
            orig = jax.lax.dynamic_slice_in_dim(cb, pe, c, axis=0)
            win = jnp.where(keep,
                            jnp.take(nb.astype(cb.dtype),
                                     jnp.clip(idx, 0, c - 1), axis=0), orig)
            return jax.lax.dynamic_update_slice_in_dim(cb, win, pe, axis=0)
        return jax.vmap(one)(cache, new, pos, lens)

    k_cache = _scatter(k_cache, k_new)
    v_cache = _scatter(v_cache, v_new)
    # padded query rows (>= len_b) get q_pos −1: their mask row is all-False
    # (uniform-softmax garbage the caller never reads or writes back)
    q_pos = jnp.where(posv < pos[:, None] + lens[:, None], posv, -1)
    if backend == "pallas" and c == 1:
        from repro.kernels import ops as kops
        o = kops.batched_decode_attention_auto(q[:, 0], k_cache, v_cache,
                                               pos + 1, window=window,
                                               num_meta=num_meta,
                                               alibi=alibi)[:, None]
    else:
        mask = build_mask(q_pos, kv_positions, causal=True, window=window,
                          num_meta=num_meta)
        bias = (alibi_bias(alibi, q_pos, jnp.maximum(kv_positions, 0))
                if alibi is not None else None)
        o = attend(q, k_cache, v_cache, mask=mask, bias=bias, backend="xla")
    return out_proj(o, p), k_cache, v_cache


def cross_attention(x, p, cfg, k_cache, v_cache, backend: str = "xla"):
    """Decoder→encoder cross attention (no mask, no rope)."""
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, dh)
    o = attend(q, k_cache, v_cache, mask=None, backend=backend)
    return out_proj(o, p)


def cross_kv(enc_out, p, cfg):
    """Compute cross-attention K/V once from encoder output."""
    b, s, _ = enc_out.shape
    dh = cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(b, s, cfg.num_kv_heads, dh)
    v = (enc_out @ p["wv"]).reshape(b, s, cfg.num_kv_heads, dh)
    return k, v
