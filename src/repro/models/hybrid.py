"""Hymba-style hybrid LM: parallel attention + Mamba heads per layer.

Every layer runs attention and an SSD block in PARALLEL on the same normed
input; their rms-normalized outputs are mean-fused.  Most layers use
sliding-window attention (ring-buffer KV cache of size window+meta) while
``full_attn_layers`` use global attention.  ``num_meta_tokens`` learnable meta
tokens are prepended and remain attendable from every window (Hymba §3).

Layer stacks are scanned per contiguous SWA segment; the few global layers run
unrolled.  Decode state (see kvcache/cache.py):
  kv_swa [Lswa,B,M+W,Hkv,Dh] ring, kv_full [Lfull,B,M+S,Hkv,Dh],
  swa_pos [M+W] absolute positions per slot, conv + ssd states for all layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ssm
from repro.models.common import embed_init, norm_apply, norm_init, rmsnorm, split_keys
from repro.models.losses import causal_lm_loss
from repro.models.mlp import mlp_apply, mlp_init


def _segments(cfg: ArchConfig):
    """[('full', layer_idx, full_idx) | ('swa', start, stop, swa_start)]"""
    full = set(cfg.full_attn_layers)
    segs, i, swa_count, full_count = [], 0, 0, 0
    while i < cfg.num_layers:
        if i in full:
            segs.append(("full", i, full_count))
            full_count += 1
            i += 1
        else:
            j = i
            while j < cfg.num_layers and j not in full:
                j += 1
            segs.append(("swa", i, j, swa_count))
            swa_count += j - i
            i = j
    return segs


class HybridLM:
    def __init__(self, cfg: ArchConfig, backend: str = "xla", remat: bool = False):
        self.cfg = cfg
        self.backend = backend
        self.remat = remat
        self.segs = _segments(cfg)
        self.n_full = len(cfg.full_attn_layers)
        self.n_swa = cfg.num_layers - self.n_full

    # ------------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        kE, kM, kL, kH = split_keys(key, 4)
        p = {"embed": embed_init(kE, (cfg.vocab_size, cfg.d_model), dtype),
             "meta": embed_init(kM, (cfg.num_meta_tokens, cfg.d_model), dtype)}

        def one_layer(k):
            k1, k2, k3 = split_keys(k, 3)
            return {"ln1": norm_init(cfg.norm, cfg.d_model, dtype),
                    "attn": attn.attn_init(k1, cfg, dtype),
                    "ssm": ssm.ssm_init(k2, cfg, dtype),
                    "fuse_na": jnp.zeros((cfg.d_model,), dtype),
                    "fuse_ns": jnp.zeros((cfg.d_model,), dtype),
                    "ln2": norm_init(cfg.norm, cfg.d_model, dtype),
                    "mlp": mlp_init(k3, cfg, dtype)}

        keys = split_keys(kL, cfg.num_layers)
        p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *[one_layer(k) for k in keys])
        p["final_norm"] = norm_init(cfg.norm, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(kH, (cfg.d_model, cfg.vocab_size), dtype)
        return p

    def _unembed(self, params, x):
        head = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        return x @ head

    # ------------------------------------------------------------------
    def _layer_parallel(self, x, lp, positions, window, conv0=None, h0=None):
        """Full-sequence layer: returns (x, k, v, ssd_state, conv_state)."""
        cfg = self.cfg
        h = norm_apply(cfg.norm, x, lp["ln1"])
        a_out, k, v = attn.attention_prefill(
            h, lp["attn"], cfg, positions, window=window,
            num_meta=cfg.num_meta_tokens, backend=self.backend)
        s_out, hfin, conv = ssm.ssm_prefill(h, lp["ssm"], cfg, h0=h0, conv0=conv0,
                                            backend=self.backend)
        fused = 0.5 * (rmsnorm(a_out, lp["fuse_na"]) + rmsnorm(s_out, lp["fuse_ns"]))
        x = x + fused
        x = x + mlp_apply(norm_apply(cfg.norm, x, lp["ln2"]), lp["mlp"], cfg)
        return x, k, v, hfin, conv

    def _forward(self, params, tokens, collect: bool):
        """Full-sequence forward.  Returns (x, cache_parts or None)."""
        cfg = self.cfg
        b, s = tokens.shape
        m = cfg.num_meta_tokens
        x = jnp.take(params["embed"], tokens, axis=0)
        meta = jnp.broadcast_to(params["meta"][None], (b, m, cfg.d_model)).astype(x.dtype)
        x = jnp.concatenate([meta, x], axis=1)
        st = m + s
        positions = jnp.arange(st, dtype=jnp.int32)
        w = cfg.sliding_window

        # ring-slot gather indices for the SWA cache (static, numpy)
        ring = np.full((m + w,), -1, np.int64)
        ring[:m] = np.arange(m)
        for p_abs in range(max(m, st - w), st):
            ring[m + (p_abs - m) % w] = p_abs
        valid = ring >= 0
        gather_idx = np.where(valid, ring, 0)

        ks_full, vs_full, ks_swa, vs_swa = [], [], [], []
        convs, ssds = [None] * cfg.num_layers, [None] * cfg.num_layers

        for seg in self.segs:
            if seg[0] == "full":
                _, li, _ = seg
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                x, k, v, hfin, conv = self._layer_parallel(x, lp, positions, window=0)
                if collect:
                    ks_full.append(k); vs_full.append(v)
                    convs[li], ssds[li] = conv, hfin
            else:
                _, lo, hi, _ = seg
                lps = jax.tree.map(lambda a: a[lo:hi], params["layers"])

                def body(x, lp):
                    x, k, v, hfin, conv = self._layer_parallel(x, lp, positions, window=w)
                    kw = jnp.take(k, gather_idx, axis=1) * valid[None, :, None, None]
                    vw = jnp.take(v, gather_idx, axis=1) * valid[None, :, None, None]
                    return x, (kw, vw, hfin, conv)

                if self.remat and not collect:
                    body = jax.checkpoint(body)
                x, (kw, vw, hf, cv) = jax.lax.scan(body, x, lps)
                if collect:
                    ks_swa.append(kw); vs_swa.append(vw)
                    for off in range(hi - lo):
                        convs[lo + off] = jax.tree.map(lambda a: a[off], cv)
                        ssds[lo + off] = jax.tree.map(lambda a: a[off], hf)

        x = norm_apply(cfg.norm, x, params["final_norm"])
        if not collect:
            return x, None
        cache = {
            "kv_full": {"k": jnp.stack(ks_full), "v": jnp.stack(vs_full)},
            "kv_swa": {"k": jnp.concatenate(ks_swa), "v": jnp.concatenate(vs_swa)},
            "swa_pos": jnp.asarray(ring, jnp.int32),
            "conv": jnp.stack(convs),
            "ssd": jnp.stack(ssds),
        }
        return x, cache

    # ------------------------------------------------------------------
    def loss(self, params, batch):
        x, _ = self._forward(params, batch["tokens"], collect=False)
        x = x[:, self.cfg.num_meta_tokens:]
        logits = self._unembed(params, x)
        return causal_lm_loss(logits, batch["targets"], batch["loss_mask"])

    def prefill(self, params, batch, max_len=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        s = tokens.shape[1]
        x, cache = self._forward(params, tokens, collect=True)
        logits = self._unembed(params, x[:, -1:, :])[:, 0]
        cur = cfg.num_meta_tokens + s
        if max_len is not None and max_len > cur:  # grow full-attn cache (total slots)
            pad = max_len - cur
            for kk in ("k", "v"):
                arr = cache["kv_full"][kk]
                cache["kv_full"][kk] = jnp.pad(arr, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return logits, cache, jnp.int32(cur)

    # ------------------------------------------------------------------
    def decode_step(self, params, state, token, pos):
        """pos: absolute position (meta offset included) of the new token."""
        cfg = self.cfg
        m, w = cfg.num_meta_tokens, cfg.sliding_window
        x = jnp.take(params["embed"], token[:, None], axis=0)

        slot = m + jnp.remainder(pos - m, w)
        swa_pos = state["swa_pos"].at[slot].set(pos)
        full_len = state["kv_full"]["k"].shape[2]
        full_pos = jnp.arange(full_len, dtype=jnp.int32)
        full_pos = jnp.where(full_pos <= pos, full_pos, -1)

        new_full_k, new_full_v = [None] * self.n_full, [None] * self.n_full
        new_swa_k, new_swa_v = [], []
        new_conv, new_ssd = [None] * cfg.num_layers, [None] * cfg.num_layers

        def one(x, lp, kc, vc, conv, ssd_st, window, kv_positions, write_index):
            h = norm_apply(cfg.norm, x, lp["ln1"])
            a_out, kc, vc = attn.attention_decode(
                h, lp["attn"], cfg, kc, vc, kv_positions, pos,
                window=window, num_meta=m, write_index=write_index,
                backend=self.backend)
            s_out, ssd_st, conv = ssm.ssm_decode(h, lp["ssm"], cfg, ssd_st, conv)
            fused = 0.5 * (rmsnorm(a_out, lp["fuse_na"]) + rmsnorm(s_out, lp["fuse_ns"]))
            x = x + fused
            x = x + mlp_apply(norm_apply(cfg.norm, x, lp["ln2"]), lp["mlp"], cfg)
            return x, kc, vc, conv, ssd_st

        for seg in self.segs:
            if seg[0] == "full":
                _, li, fi = seg
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                kc = state["kv_full"]["k"][fi]
                vc = state["kv_full"]["v"][fi]
                x, kc, vc, conv, sst = one(x, lp, kc, vc, state["conv"][li],
                                           state["ssd"][li], 0, full_pos, pos)
                new_full_k[fi], new_full_v[fi] = kc, vc
                new_conv[li], new_ssd[li] = conv, sst
            else:
                _, lo, hi, so = seg
                n = hi - lo
                lps = jax.tree.map(lambda a: a[lo:hi], params["layers"])
                kcs = state["kv_swa"]["k"][so:so + n]
                vcs = state["kv_swa"]["v"][so:so + n]
                convs = state["conv"][lo:hi]
                ssds = state["ssd"][lo:hi]

                def body(x, xs):
                    lp, kc, vc, conv, sst = xs
                    x, kc, vc, conv, sst = one(x, lp, kc, vc, conv, sst,
                                               w, swa_pos, slot)
                    return x, (kc, vc, conv, sst)

                x, (kcs, vcs, convs, ssds) = jax.lax.scan(body, x, (lps, kcs, vcs, convs, ssds))
                new_swa_k.append(kcs); new_swa_v.append(vcs)
                for off in range(n):
                    new_conv[lo + off] = jax.tree.map(lambda a: a[off], convs)
                    new_ssd[lo + off] = jax.tree.map(lambda a: a[off], ssds)

        x = norm_apply(cfg.norm, x, params["final_norm"])
        logits = self._unembed(params, x)[:, 0]
        new_state = {
            "kv_full": {"k": jnp.stack(new_full_k), "v": jnp.stack(new_full_v)},
            "kv_swa": {"k": jnp.concatenate(new_swa_k), "v": jnp.concatenate(new_swa_v)},
            "swa_pos": swa_pos,
            "conv": jnp.stack(new_conv),
            "ssd": jnp.stack(new_ssd),
        }
        return logits, new_state
