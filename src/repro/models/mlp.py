"""Dense MLP blocks: gated (SiLU/LLaMA-style) and plain (GELU / squared-ReLU)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import activation_fn, dense_init, logical_constraint, split_keys


def mlp_init(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.activation == "silu":  # gated
        k1, k2, k3 = split_keys(key, 3)
        return {"w_gate": dense_init(k1, (d, ff), dtype),
                "w_up": dense_init(k2, (d, ff), dtype),
                "w_down": dense_init(k3, (ff, d), dtype)}
    k1, k2 = split_keys(key, 2)
    return {"w_up": dense_init(k1, (d, ff), dtype),
            "w_down": dense_init(k2, (ff, d), dtype)}


def mlp_apply(x, p, cfg):
    act = activation_fn(cfg.activation)
    x = logical_constraint(x, "batch", "mlp_seq", None)
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = act(x @ p["w_up"])
    h = logical_constraint(h, "batch", "mlp_seq", "ff")
    out = h @ p["w_down"]
    return logical_constraint(out, "batch", "mlp_seq", None)
