"""Shared model building blocks: norms, activations, positional encodings.

All models are pure-functional: params are nested dicts of jnp arrays with a
leading ``[L, ...]`` layer axis for scanned stacks.  Compute dtype is bf16 with
f32 accumulation in norms/softmax; parameters are stored in the config dtype.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Activation / logical-sharding helpers
# ---------------------------------------------------------------------------

_LOGICAL_RULES: Optional[dict] = None  # set by repro.distributed.sharding


def set_logical_rules(rules):
    """Install activation logical-axis → mesh-axis rules (hillclimb lever)."""
    global _LOGICAL_RULES
    _LOGICAL_RULES = rules


def logical_constraint(x, *names):
    """Apply ``with_sharding_constraint`` using installed logical rules.

    No-op when no rules are installed (single-device tests) or when the name
    has no mapping.  ``names`` has one entry per axis of ``x`` (None = leave).
    Axes whose size is not divisible by the mesh-axis extent are left
    unconstrained (GSPMD would PAD them — e.g. batch=1 padded 16×).
    """
    if _LOGICAL_RULES is None:
        return x
    from jax.sharding import PartitionSpec as P
    sizes = _LOGICAL_RULES.get("__sizes__", {})

    def extent(axis):
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= sizes.get(a, 1)
            return n
        return sizes.get(axis, 1)

    spec = []
    for dim, n in zip(x.shape, names):
        axis = _LOGICAL_RULES.get(n) if n else None
        if axis is not None and sizes and dim % max(extent(axis), 1) != 0:
            axis = None
        spec.append(axis)
    if all(s is None for s in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x  # outside a mesh context


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dtype)


def norm_apply(kind: str, x, p):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def norm_init(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, Dh]; positions: [..., S] int32 (broadcastable)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta))                  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]                         # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def alibi_slopes(num_heads: int):
    """ALiBi per-head slopes (BLOOM)."""
    def pow2slopes(n):
        start = 2.0 ** (-(2.0 ** -(math.log2(n) - 3)))
        return [start * (start ** i) for i in range(n)]
    if math.log2(num_heads).is_integer():
        return np.asarray(pow2slopes(num_heads), np.float32)
    n = 2 ** math.floor(math.log2(num_heads))
    base = pow2slopes(n)
    extra = pow2slopes(2 * n)[0::2][: num_heads - n]
    return np.asarray(base + extra, np.float32)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (0.02 * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
