"""Mamba-2 LM (attention-free SSD backbone).

Decode state = {"conv": [L,B,K-1,conv_dim], "ssd": [L,B,nh,hd,N] f32} — the
fixed-size generalization of the KV cache for DéjàVu streaming.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ssm
from repro.models.common import embed_init, logical_constraint, norm_apply, norm_init, split_keys
from repro.models.losses import causal_lm_loss


class MambaLM:
    def __init__(self, cfg: ArchConfig, backend: str = "xla", remat: bool = False):
        self.cfg = cfg
        self.backend = backend
        self.remat = remat

    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        kE, kL, kH = split_keys(key, 3)
        p = {"embed": embed_init(kE, (cfg.vocab_size, cfg.d_model), dtype)}

        def one_layer(k):
            return {"ln": norm_init(cfg.norm, cfg.d_model, dtype),
                    "ssm": ssm.ssm_init(k, cfg, dtype)}

        keys = split_keys(kL, cfg.num_layers)
        p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *[one_layer(k) for k in keys])
        p["final_norm"] = norm_init(cfg.norm, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(kH, (cfg.d_model, cfg.vocab_size), dtype)
        return p

    def _unembed(self, params, x):
        head = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        return logical_constraint(x @ head, "batch", None, "vocab")

    def _forward(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)

        def body(x, lp):
            h = norm_apply(cfg.norm, x, lp["ln"])
            out, hfin, conv = ssm.ssm_prefill(h, lp["ssm"], cfg, backend=self.backend)
            return x + out, (hfin, conv)

        if self.remat:
            body = jax.checkpoint(body)
        x, (hs, convs) = jax.lax.scan(body, x, params["layers"])
        x = norm_apply(cfg.norm, x, params["final_norm"])
        return x, hs, convs

    def loss(self, params, batch):
        x, _, _ = self._forward(params, batch["tokens"])
        logits = self._unembed(params, x)
        return causal_lm_loss(logits, batch["targets"], batch["loss_mask"])

    def prefill(self, params, batch, max_len=None):
        x, hs, convs = self._forward(params, batch["tokens"])
        logits = self._unembed(params, x[:, -1:, :])[:, 0]
        state = {"conv": convs, "ssd": hs}
        return logits, state, jnp.int32(batch["tokens"].shape[1])

    def decode_step(self, params, state, token, pos):
        cfg = self.cfg
        x = jnp.take(params["embed"], token[:, None], axis=0)

        def body(x, xs):
            lp, conv, h = xs
            hin = norm_apply(cfg.norm, x, lp["ln"])
            out, h, conv = ssm.ssm_decode(hin, lp["ssm"], cfg, h, conv)
            return x + out, (conv, h)

        x, (convs, hs) = jax.lax.scan(body, x, (params["layers"], state["conv"], state["ssd"]))
        x = norm_apply(cfg.norm, x, params["final_norm"])
        logits = self._unembed(params, x)[:, 0]
        return logits, {"conv": convs, "ssd": hs}
