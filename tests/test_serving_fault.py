"""End-to-end DéjàVu cluster behaviour: every feature must generate tokens
bit-identical to whole-model generation (greedy sampling is deterministic)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import PAPER_ARCHS
from repro.models import build_model
from repro.serving import Request, ServingEngine

pytestmark = pytest.mark.slow  # full sweep; excluded from `pytest -m "not slow"`

CFG = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                          dtype="float32", num_layers=8)
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
RNG = np.random.default_rng(0)
PROMPTS = RNG.integers(0, CFG.vocab_size, (4, 8)).astype(np.int32)
N_NEW = 6


def mkreqs():
    return [Request(rid=i, prompt=PROMPTS[i].copy(), max_new=N_NEW)
            for i in range(4)]


@pytest.fixture(scope="module")
def reference_tokens():
    logits, state, pos = MODEL.prefill(
        PARAMS, {"tokens": jnp.asarray(PROMPTS[:2])},
        max_len=PROMPTS.shape[1] + N_NEW)
    toks = [np.asarray(jnp.argmax(logits, -1), np.int32)]
    for _ in range(1, N_NEW):
        logits, state = MODEL.decode_step(PARAMS, state,
                                          jnp.asarray(toks[-1]), pos)
        pos = pos + 1
        toks.append(np.asarray(jnp.argmax(logits, -1), np.int32))
    return np.stack(toks, 1)        # [2, N_NEW]


@pytest.fixture(scope="module")
def baseline_report():
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated", microbatch=2)
    return eng.run(mkreqs())


def test_colocated_pipeline_matches_whole_model(reference_tokens, baseline_report):
    got = np.array([baseline_report.tokens[0], baseline_report.tokens[1]])
    np.testing.assert_array_equal(got, reference_tokens)


def test_disaggregated_matches_baseline(baseline_report):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="disaggregated",
                        dp_split=(2, 2), microbatch=2)
    rep = eng.run(mkreqs())
    assert rep.tokens == baseline_report.tokens
    # prompt KV actually crossed the network
    assert eng.transfer_summary()["net"] > 0


def test_disaggregated_uneven_split(baseline_report):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="disaggregated",
                        dp_split=(1, 3), microbatch=2)
    rep = eng.run(mkreqs())
    assert rep.tokens == baseline_report.tokens


def test_swapping_matches_baseline(baseline_report):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated",
                        microbatch=2, swapping=True)
    rep = eng.run(mkreqs())
    assert rep.tokens == baseline_report.tokens
    assert eng.transfer_summary()["hostlink"] > 0   # swaps really moved bytes


# 2 microbatches × 6 steps = 12 global steps; fail points must be ≤ 12
@pytest.mark.parametrize("fail_step,wid", [(9, 2), (5, 0), (12, 3)])
def test_failure_recovery_regenerates_identical_tokens(
        baseline_report, fail_step, wid):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated",
                        microbatch=2, replication=True)
    rep = eng.run(mkreqs(), fail_at={fail_step: wid})
    assert rep.failures == 1 and rep.recoveries == 1
    assert rep.tokens == baseline_report.tokens
    kinds = [e["kind"] for e in eng.cluster.controller.events]
    assert "failure" in kinds and "recovery" in kinds


def test_failure_without_replication_would_lose_state(baseline_report):
    """Sanity: replication is what makes recovery possible — the recovered
    worker's caches come from the ring replica."""
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated",
                        microbatch=2, replication=True)
    rep = eng.run(mkreqs(), fail_at={9: 2})
    # replica stores on the ring successor were populated before the failure
    assert rep.tokens == baseline_report.tokens


def test_straggler_migration(baseline_report):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated",
                        microbatch=2, replication=True)
    rep = eng.run(mkreqs(), migrate_at={7: 1})
    assert rep.tokens == baseline_report.tokens
    kinds = [e["kind"] for e in eng.cluster.controller.events]
    assert "migrate" in kinds


def test_elastic_repartition(baseline_report):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated", microbatch=2)
    rep = eng.run(mkreqs(), repartition_at={10: 3})
    assert rep.tokens == baseline_report.tokens
    assert len(eng.cluster.token_group) == 3


def test_swapping_plus_replication_with_failure(baseline_report):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated", microbatch=2,
                        swapping=True, replication=True)
    rep = eng.run(mkreqs(), fail_at={11: 1})
    assert rep.tokens == baseline_report.tokens


def test_disaggregated_prompt_worker_failure(baseline_report):
    """Prompt workers are stateless; failing one mid-serve must not corrupt
    token generation."""
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="disaggregated",
                        dp_split=(2, 2), microbatch=2, replication=True)
    rep = eng.run(mkreqs(), fail_at={8: 0})
    assert rep.tokens == baseline_report.tokens


def test_compressed_replication_halves_wire_bytes_and_recovers():
    """Beyond-paper: int8 KV replication — wire bytes ~halve vs bf16; recovery
    restores from dequantized replicas and serving completes (small
    quantization error only enters state after an actual failure)."""
    eng_full = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated",
                             microbatch=2, replication=True)
    rep_full = eng_full.run(mkreqs())
    bytes_full = eng_full.transfer_summary()["net"]

    eng_c = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated",
                          microbatch=2, replication=True,
                          compress_replicas=True)
    rep_c = eng_c.run(mkreqs())
    bytes_c = eng_c.transfer_summary()["net"]
    # bf16 is f32 in this test config -> int8 is 4x fewer wire bytes here
    assert bytes_c < 0.6 * bytes_full
    assert rep_c.tokens == rep_full.tokens      # no failure -> identical

    # with a failure, recovery uses dequantized replicas; serving completes
    eng_f = ServingEngine(CFG, MODEL, PARAMS, 4, mode="colocated",
                          microbatch=2, replication=True,
                          compress_replicas=True)
    rep_f = eng_f.run(mkreqs(), fail_at={9: 2})
    assert rep_f.recoveries == 1
    assert all(len(t) == N_NEW for t in rep_f.tokens.values())
