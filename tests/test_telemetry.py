"""Unified telemetry layer (repro.core.telemetry): instrument semantics,
snapshot stability, byte-identical determinism across identical serving
runs, and per-mode coverage of the required SLO instruments."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import telemetry
from repro.core.dejavulib import faults

# ---------------------------------------------------------------------------
# unit level: instruments
# ---------------------------------------------------------------------------


def test_counter_gauge_labels():
    t = telemetry.Telemetry()
    t.count("c", 2, kind="net")
    t.count("c", 3, kind="net")
    t.count("c", 1, kind="ici")
    t.gauge("g", 0.5)
    t.gauge("g", 0.25)                       # last write wins
    snap = t.snapshot()
    assert snap["schema"] == telemetry.SCHEMA
    assert snap["counters"] == {"c{kind=ici}": 1, "c{kind=net}": 5}
    assert snap["gauges"] == {"g": 0.25}


def test_label_key_is_sorted():
    assert telemetry._label_key("n", {"b": 1, "a": 2}) == "n{a=2,b=1}"


def test_count_time_integer_ns():
    t = telemetry.Telemetry()
    # float-accumulation would drift with ordering; ns-ints cannot
    for _ in range(1000):
        t.count_time("t_ns", 0.1e-6)
    assert t.snapshot()["counters"]["t_ns"] == 1000 * 100


def test_histogram_quantiles_and_bounds():
    h = telemetry.Histogram()
    assert h.quantile(0.5) == 0.0            # empty
    vals = [1e-5, 2e-5, 3e-5, 4e-5, 1e-3]
    for v in vals:
        h.observe(v)
    assert h.count == len(vals)
    assert h.min == 1e-5 and h.max == 1e-3
    # quantiles are deterministic, clamped to [min, max], monotone
    q = [h.quantile(x) for x in (0.5, 0.9, 0.99)]
    assert all(h.min <= v <= h.max for v in q)
    assert q[0] <= q[1] <= q[2]
    h2 = telemetry.Histogram()
    h2.observe(1e9)                          # above the last edge: overflow
    assert h2.counts[-1] == 1
    assert h2.quantile(0.99) == 1e9          # clamped to max


def test_span_nesting_paths_and_tags():
    t = telemetry.Telemetry()
    with t.span("round"):
        t.advance(1.0)
        with t.span("pass", kind="fused_decode"):
            t.advance(0.25)
    snap = t.snapshot()["spans"]
    assert snap["round"]["count"] == 1
    assert snap["round"]["total_s"] == pytest.approx(1.25)
    inner = snap["round/pass[kind=fused_decode]"]
    assert inner["count"] == 1
    assert inner["total_s"] == pytest.approx(0.25)


def test_module_helpers_noop_when_uninstalled():
    assert telemetry.current() is None
    telemetry.count("x")                     # all must be silent no-ops
    telemetry.observe("x", 1.0)
    telemetry.gauge("x", 1.0)
    telemetry.advance(1.0)
    assert telemetry.clock() == 0.0
    with telemetry.span("x"):
        pass


def test_advance_and_span_raise_off_owner_thread():
    """The modeled clock is single-writer: mutating it from the streamer
    thread would make timestamps racy, so the registry refuses."""
    import threading
    t = telemetry.Telemetry()
    errs = []

    def worker():
        for fn in (lambda: t.advance(1.0), lambda: t.span("x").__enter__()):
            try:
                fn()
            except RuntimeError as e:
                errs.append(e)

    th = threading.Thread(target=worker)
    th.start()
    th.join()
    assert len(errs) == 2
    assert all("non-owner thread" in str(e) for e in errs)
    # counters/histograms stay thread-safe: no guard on those
    t.count("from_main")
    assert t.snapshot()["counters"]["from_main"] == 1


def test_install_uninstall_restores_previous():
    a = telemetry.Telemetry()
    prev = telemetry.install(a)
    assert prev is None
    b = telemetry.Telemetry()
    prev = telemetry.install(b)
    assert prev is a
    telemetry.uninstall(prev)
    assert telemetry.current() is a
    telemetry.uninstall()
    assert telemetry.current() is None


def test_snapshot_json_round_trip_stable_ordering():
    t = telemetry.Telemetry()
    t.count("z", 1)
    t.count("a", 1)
    t.observe("h", 1e-4)
    with t.span("s", b=1, a=2):
        t.advance(0.5)
    s = t.to_json()
    doc = json.loads(s)
    assert json.dumps(doc, sort_keys=True, separators=(",", ":")) == s
    assert list(doc["counters"]) == ["a", "z"]   # sorted keys survive


# ---------------------------------------------------------------------------
# engine level: determinism + per-mode coverage of required instruments
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    import jax
    from repro.configs.registry import PAPER_ARCHS
    from repro.models import build_model

    cfg = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                              dtype="float32", num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)
    return cfg, model, params, prompts


def _mkreqs(prompts, max_new=4):
    from repro.serving import Request
    return [Request(rid=i, prompt=p.copy(), max_new=max_new)
            for i, p in enumerate(prompts)]


def _run_tiered_faulted(served):
    """One continuous run: fused + tiered + one injected (delay) fault."""
    from repro.serving import ServingEngine
    cfg, model, params, prompts = served
    eng = ServingEngine(cfg, model, params, 2, paged=True, tiered=True,
                        kv_pool_blocks=128, host_cache_blocks=16,
                        ssd_cache_blocks=32)
    plan = faults.FaultPlan([faults.FaultSpec(
        "stream.task", nth=2, kind="delay", delay_s=1e-3)])
    rep = eng.run_continuous(_mkreqs(prompts), max_active=2, fault_plan=plan)
    assert rep.fault_trace, "the injected fault never fired"
    return rep


def test_determinism_byte_identical_snapshots(served):
    """Two identical runs (fused + tiered + one injected fault) must produce
    byte-identical telemetry JSON — the module's headline guarantee."""
    a = _run_tiered_faulted(served)
    b = _run_tiered_faulted(served)
    ja = json.dumps(a.telemetry, sort_keys=True, separators=(",", ":"))
    jb = json.dumps(b.telemetry, sort_keys=True, separators=(",", ":"))
    assert ja == jb
    assert a.telemetry["schema"] == telemetry.SCHEMA


def test_tiered_run_populates_tier_and_fault_instruments(served):
    rep = _run_tiered_faulted(served)
    tele = rep.telemetry
    assert tele["counters"]["faults.fired{kind=delay,point=stream.task}"] == 1
    # tiered serving moved blocks: tier counters + stream/transport activity
    assert any(k.startswith("tier.") for k in tele["counters"])
    assert tele["counters"]["stream.tasks_submitted"] > 0
    assert any(k.startswith("transport.bytes{") for k in tele["counters"])


def _required_slo_keys(tele, max_new):
    assert tele["schema"] == telemetry.SCHEMA
    assert tele["histograms"]["engine.ttft_s"]["count"] >= 1
    if max_new > 1:
        it = tele["histograms"]["engine.inter_token_s"]
        assert it["count"] >= 1
        assert it["p50_s"] <= it["p99_s"]
    assert "engine.bubble_frac" in tele["gauges"]
    assert any(k.startswith("pass") or "/pass" in k for k in tele["spans"])


def test_mode_coverage_perseq_and_fused(served):
    """run_continuous, per-seq oracle vs fused rounds: both snapshots carry
    the SLO histograms; replication makes transport bytes flow."""
    from repro.serving import ServingEngine
    cfg, model, params, prompts = served
    for fused in (False, True):
        eng = ServingEngine(cfg, model, params, 2, paged=True,
                            kv_pool_blocks=128, replication=True,
                            fused_rounds=fused)
        rep = eng.run_continuous(_mkreqs(prompts), max_active=3)
        tele = rep.telemetry
        _required_slo_keys(tele, 4)
        assert any(k.startswith("transport.bytes{") for k in tele["counters"])
        kind = "fused_decode" if fused else "perseq_decode"
        assert any(f"kind={kind}" in k for k in tele["spans"]), \
            f"no {kind} pass span in {sorted(tele['spans'])}"


def test_mode_coverage_disagg_and_swap(served):
    """run() in disaggregated and swapping modes: SLO keys + per-link bytes
    (disagg streams prompt KV; swapping moves microbatch KV to host)."""
    from repro.serving import ServingEngine
    cfg, model, params, prompts = served
    eng = ServingEngine(cfg, model, params, 2, mode="disaggregated",
                        dp_split=(1, 1), microbatch=2)
    rep = eng.run(_mkreqs(prompts))
    _required_slo_keys(rep.telemetry, 4)
    assert any(k.startswith("transport.bytes{")
               for k in rep.telemetry["counters"])

    eng = ServingEngine(cfg, model, params, 2, microbatch=2, swapping=True)
    rep = eng.run(_mkreqs(prompts))
    _required_slo_keys(rep.telemetry, 4)
    assert any(k.startswith("transport.bytes{")
               for k in rep.telemetry["counters"])


def test_recovery_span_populated_on_failure(served):
    """fail_at -> cluster.recovery_s histogram: the time from the injected
    failure to the first post-restore token on the modeled clock."""
    from repro.serving import ServingEngine
    cfg, model, params, prompts = served
    eng = ServingEngine(cfg, model, params, 2, microbatch=2,
                        replication=True)
    rep = eng.run(_mkreqs(prompts), fail_at={3: 1})
    assert rep.recoveries == 1
    rec = rep.telemetry["histograms"]["cluster.recovery_s"]
    assert rec["count"] >= 1
    assert rec["max_s"] < 60.0
    assert rep.telemetry["counters"]["cluster.failures"] == 1


def test_ambient_registry_aggregates_and_is_reused(served):
    """With an ambient registry installed (the benchmarks' pattern), runs
    aggregate into it and the engine does NOT uninstall it."""
    from repro.serving import ServingEngine
    cfg, model, params, prompts = served
    amb = telemetry.Telemetry()
    telemetry.install(amb)
    try:
        eng = ServingEngine(cfg, model, params, 2, paged=True,
                            kv_pool_blocks=128)
        r1 = eng.run_continuous(_mkreqs(prompts), max_active=3)
        assert telemetry.current() is amb
        c1 = r1.telemetry["histograms"]["engine.ttft_s"]["count"]
        r2 = eng.run_continuous(_mkreqs(prompts), max_active=3)
        c2 = r2.telemetry["histograms"]["engine.ttft_s"]["count"]
        assert c2 == 2 * c1                  # cumulative across runs
    finally:
        telemetry.uninstall()


def test_queue_wait_and_admission_counters(served):
    """max_active=1 forces queueing: admissions counted, waits observed."""
    from repro.serving import ServingEngine
    cfg, model, params, prompts = served
    eng = ServingEngine(cfg, model, params, 2, paged=True,
                        kv_pool_blocks=128)
    rep = eng.run_continuous(_mkreqs(prompts), max_active=1)
    tele = rep.telemetry
    assert tele["counters"]["engine.admitted"] == len(prompts)
    qw = tele["histograms"]["engine.queue_wait_s"]
    assert qw["count"] == len(prompts)
    assert qw["max_s"] > 0.0                 # later requests waited
