"""Fused batched rounds: ONE pipeline pass per decode round.

Token identity: with `fused_rounds` on (the DEFAULT), every trace must
reproduce the per-sequence oracle path (`fused_rounds=False`) bit-for-bit
— across prompt mixes,
chunked prefill + prefix adoption, preemption, and mid-trace worker
failures (greedy regeneration is deterministic, so any pass packing that
computes the same per-sequence math yields the same tokens).  Shape: an
8-active decode round executes one batched pass, `EngineReport.pass_trace`
records it.  Plus the disaggregated admission-discount regression
(cluster.can_admit) and the planner/costmodel round-time terms.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import plan
from repro.models import build_model
from repro.serving import Request, ServingEngine

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # optional dev dep (requirements-dev.txt)
    HAVE_HYPOTHESIS = False

CFG = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                          dtype="float32", num_layers=2)
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
RNG = np.random.default_rng(0)


def engine(**kw):
    return ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, **kw)


def mkreqs(prompts, max_new=4):
    if isinstance(max_new, int):
        max_new = [max_new] * len(prompts)
    return [Request(rid=i, prompt=p.copy(), max_new=m)
            for i, (p, m) in enumerate(zip(prompts, max_new))]


def _prompts(n, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, (lens[i % len(lens)],)
                         ).astype(np.int32) for i in range(n)]


# ---------------------------------------------------------------------------
# token identity + pass shape
# ---------------------------------------------------------------------------

def test_fused_token_identity_mixed_trace():
    prompts = _prompts(6, [8, 12])
    mx = [6, 3, 7, 4, 3, 6]
    base = engine(kv_pool_blocks=64, fused_rounds=False).run_continuous(
        mkreqs(prompts, mx), max_active=4)
    fus = engine(kv_pool_blocks=64).run_continuous(
        mkreqs(prompts, mx), max_active=4)
    assert fus.tokens == base.tokens
    assert fus.batch_trace == base.batch_trace
    # fused rounds do strictly fewer pipeline passes on the same trace
    assert sum(fus.pass_trace) < sum(base.pass_trace)


def test_fused_8_active_round_is_one_pass():
    """Acceptance: an 8-active decode round = ONE batched pipeline pass
    (the oracle path runs 8), with token-identical output."""
    prompts = _prompts(8, [8])
    base = engine(kv_pool_blocks=256, fused_rounds=False).run_continuous(
        mkreqs(prompts, 6), max_active=8)
    fus = engine(kv_pool_blocks=256).run_continuous(
        mkreqs(prompts, 6), max_active=8)
    assert fus.tokens == base.tokens
    # rounds after the admission round hold 8 decoding sequences
    steady = [(b, p) for b, p in zip(fus.pass_trace[1:], fus.batch_trace[1:])]
    fused_steady = [p for p, b in steady if b == 8]
    assert fused_steady and all(p == 1 for p in fused_steady), fus.pass_trace
    base_steady = [p for p, b in zip(base.pass_trace[1:],
                                     base.batch_trace[1:]) if b == 8]
    assert all(p == 8 for p in base_steady), base.pass_trace


def test_fused_chunked_prefill_packs_into_one_pass():
    """Two long prompts admitted together: their chunk passes pack into ONE
    chunk-set pass per round alongside the single decode pass."""
    prompts = _prompts(2, [8]) + _prompts(2, [40], seed=3)
    kw = dict(kv_pool_blocks=128, prefill_chunk_tokens=8)
    base = engine(fused_rounds=False, **kw).run_continuous(
        mkreqs(prompts, 6), max_active=4)
    fus = engine(**kw).run_continuous(mkreqs(prompts, 6), max_active=4)
    assert fus.tokens == base.tokens
    # once admitted, a round is at most one chunk-set pass + one decode pass
    assert all(p <= 2 for p in fus.pass_trace[1:]), fus.pass_trace
    # the oracle path runs one pass per prefill chunk per round instead
    assert max(base.pass_trace[1:]) > 2, base.pass_trace
    assert fus.prefill_stall_trace == pytest.approx(base.prefill_stall_trace)


def test_fused_failure_recovery_token_identical():
    prompts = _prompts(6, [8, 12])
    mx = [6, 3, 7, 4, 3, 6]
    base = engine(kv_pool_blocks=64, fused_rounds=False).run_continuous(
        mkreqs(prompts, mx), max_active=4)
    for g, wid in ((9, 1), (5, 0)):
        eng = engine(kv_pool_blocks=64, replication=True)
        rep = eng.run_continuous(mkreqs(prompts, mx), max_active=4,
                                 fail_at={g: wid})
        assert rep.failures == 1 and rep.recoveries == 1
        assert rep.tokens == base.tokens
        kinds = [e["kind"] for e in eng.cluster.controller.events]
        assert "failure" in kinds and "recovery" in kinds


def test_fused_preemption_tiny_pool():
    prompts = _prompts(2, [8], seed=5)
    base = engine(kv_pool_blocks=64, fused_rounds=False).run_continuous(
        mkreqs(prompts, 10), max_active=2)
    fus = engine(kv_pool_blocks=4).run_continuous(
        mkreqs(prompts, 10), max_active=2)
    assert fus.preemptions >= 1
    assert fus.tokens == base.tokens


@pytest.mark.slow
def test_fused_swapping_and_tiered_adoption():
    prompts = _prompts(6, [8, 12])
    base = engine(kv_pool_blocks=64, fused_rounds=False).run_continuous(
        mkreqs(prompts, 5), max_active=4)
    rs = engine(kv_pool_blocks=64,
                swapping=True).run_continuous(mkreqs(prompts, 5),
                                              max_active=4)
    assert rs.tokens == base.tokens
    shared = _prompts(1, [16], seed=9)[0]
    sp = [np.concatenate([shared,
                          _prompts(1, [6], seed=10 + i)[0]]) for i in range(3)]
    kw = dict(tiered=True, kv_pool_blocks=128, host_cache_blocks=16,
              ssd_cache_blocks=64, prefill_chunk_tokens=4)
    oracle = engine(fused_rounds=False, **kw).run_continuous(
        mkreqs(sp, 3), max_active=2)
    fus = engine(**kw).run_continuous(mkreqs(sp, 3), max_active=2)
    assert fus.tokens == oracle.tokens
    assert fus.prefill_tokens_saved == oracle.prefill_tokens_saved > 0


def test_fused_gate_accepts_window_and_meta():
    """The batched mask path carries per-sequence window starts and meta-
    token sink bounds, so a dense config with a sliding window and/or meta
    tokens now fuses BY DEFAULT — token-identically to the per-sequence
    oracle, in strictly fewer pipeline passes.  (Before this gate was
    relaxed, such configs were hard-excluded from fusing; see
    `fused_supported` in repro.core.cluster for what still falls back.)"""
    prompts = _prompts(4, [8, 12])
    for patch in (dict(sliding_window=6),
                  dict(num_meta_tokens=2),
                  dict(sliding_window=6, num_meta_tokens=2,
                       full_attn_layers=(0,))):
        cfg = dataclasses.replace(CFG, **patch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        base = ServingEngine(cfg, model, params, 2, paged=True,
                             kv_pool_blocks=64,
                             fused_rounds=False).run_continuous(
            mkreqs(prompts, 4), max_active=3)
        eng = ServingEngine(cfg, model, params, 2, paged=True,
                            kv_pool_blocks=64)
        assert eng.cluster.fused_ok is True, patch
        rep = eng.run_continuous(mkreqs(prompts, 4), max_active=3)
        assert rep.tokens == base.tokens, patch
        assert sum(rep.pass_trace) < sum(base.pass_trace), patch


def test_fused_gate_accepts_alibi():
    """bloom-style ALiBi (pos_emb='alibi', no RoPE) fuses by default: the
    batched kernel applies per-head slopes against per-sequence lengths."""
    cfg = dataclasses.replace(PAPER_ARCHS["bloom-176b"].reduced(),
                              dtype="float32", num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(4, [8, 12])
    base = ServingEngine(cfg, model, params, 2, paged=True, kv_pool_blocks=64,
                         fused_rounds=False).run_continuous(
        mkreqs(prompts, 4), max_active=3)
    eng = ServingEngine(cfg, model, params, 2, paged=True, kv_pool_blocks=64)
    assert eng.cluster.fused_ok is True
    rep = eng.run_continuous(mkreqs(prompts, 4), max_active=3)
    assert rep.tokens == base.tokens
    assert sum(rep.pass_trace) < sum(base.pass_trace)


# ---------------------------------------------------------------------------
# property test: batched == per-sequence across random traces
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=6, deadline=None)
    @given(n=st.integers(2, 5), shared_blocks=st.integers(0, 2),
           tail=st.integers(1, 10), chunk=st.integers(0, 10),
           bs=st.sampled_from([4, 8]), max_active=st.integers(2, 4),
           pool=st.sampled_from([24, 128]),
           fail=st.one_of(st.none(), st.tuples(st.integers(3, 12),
                                               st.integers(0, 1))),
           seed=st.integers(0, 2**31 - 1))
    def test_property_fused_equals_per_sequence(n, shared_blocks, tail,
                                                chunk, bs, max_active, pool,
                                                fail, seed):
        """Any (active-set size, prompt/suffix lengths, kv block size, chunk
        size, pool pressure, mid-trace failure point): the fused batched
        rounds reproduce the per-sequence oracle's tokens exactly —
        preemptions and recoveries included."""
        rng = np.random.default_rng(seed)
        sysp = rng.integers(0, CFG.vocab_size,
                            (shared_blocks * bs,)).astype(np.int32)
        prompts = [np.concatenate([
            sysp, rng.integers(0, CFG.vocab_size,
                               (tail + (i % 3),)).astype(np.int32)])
            for i in range(n)]
        mx = [int(rng.integers(1, 6)) for _ in range(n)]
        kw = dict(kv_pool_blocks=pool, kv_block_size=bs,
                  prefill_chunk_tokens=chunk)
        fail_at = dict([fail]) if fail else None
        if fail:
            kw["replication"] = True
        base = engine(fused_rounds=False, **kw).run_continuous(
            mkreqs(prompts, mx), max_active=max_active, fail_at=fail_at)
        fus = engine(**kw).run_continuous(
            mkreqs(prompts, mx), max_active=max_active, fail_at=fail_at)
        assert fus.tokens == base.tokens

    ALIBI_CFG = dataclasses.replace(PAPER_ARCHS["bloom-176b"].reduced(),
                                    dtype="float32", num_layers=2)
    WINDOWED_CFG = dataclasses.replace(CFG, sliding_window=6,
                                       num_meta_tokens=2,
                                       full_attn_layers=(0,))

    @pytest.mark.slow
    @settings(max_examples=4, deadline=None)
    @given(cfg=st.sampled_from([ALIBI_CFG, WINDOWED_CFG]),
           n=st.integers(2, 4), tail=st.integers(1, 10),
           chunk=st.sampled_from([0, 6]), bs=st.sampled_from([4, 8]),
           pool=st.sampled_from([24, 128]),
           fail=st.one_of(st.none(), st.tuples(st.integers(3, 10),
                                               st.integers(0, 1))),
           seed=st.integers(0, 2**31 - 1))
    def test_property_fused_alibi_and_window(cfg, n, tail, chunk, bs, pool,
                                             fail, seed):
        """The newly-fusable attention variants — bloom-style ALiBi and
        hymba-style sliding-window + meta sinks with a full-attention layer
        mix — keep the fused == per-sequence identity across random prompt
        lengths, block sizes, chunking, pool pressure, and injected worker
        death."""
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(seed)
        prompts = [rng.integers(0, cfg.vocab_size,
                                (tail + 3 * (i % 3),)).astype(np.int32)
                   for i in range(n)]
        mx = [int(rng.integers(1, 6)) for _ in range(n)]
        kw = dict(kv_pool_blocks=pool, kv_block_size=bs,
                  prefill_chunk_tokens=chunk)
        fail_at = dict([fail]) if fail else None
        if fail:
            kw["replication"] = True

        def run(**extra):
            return ServingEngine(cfg, model, params, 2, paged=True,
                                 **kw, **extra).run_continuous(
                mkreqs(prompts, mx), max_active=3, fail_at=fail_at)

        base = run(fused_rounds=False)
        fus = run()
        assert fus.tokens == base.tokens


# ---------------------------------------------------------------------------
# disaggregated admission discount (cluster.can_admit regression)
# ---------------------------------------------------------------------------

def test_disaggregated_admission_counts_prefix_reuse():
    """can_admit used to consult the prefix index only in colocated mode, so
    disaggregated admission over-reserved token-side blocks for prompts
    whose prefix would be adopted/re-shared: with a 7-block pool and
    24-token prompts sharing a 2-block prefix, the second request needs 5
    blocks unshared but only 3 with the discount — it must run CONCURRENTLY
    with the first, token-identically."""
    shared = _prompts(1, [24], seed=21)[0]
    reqs = lambda: mkreqs([shared, shared], 3)                     # noqa: E731
    kw = dict(tiered=True, host_cache_blocks=16, ssd_cache_blocks=64)
    base = engine(kv_pool_blocks=64, **kw).run_continuous(reqs(), max_active=2)
    eng = ServingEngine(CFG, MODEL, PARAMS, 2, mode="disaggregated",
                        dp_split=(1, 1), paged=True, kv_pool_blocks=7, **kw)
    rep = eng.run_continuous(reqs(), max_active=2)
    assert rep.tokens == base.tokens
    assert max(rep.batch_trace) == 2, \
        f"prefix-discounted admission must run both requests: {rep.batch_trace}"
    # the token-side pool really did re-share the streamed prefix blocks
    w = eng.cluster.token_group[0]
    assert w.pool.peak_used_blocks <= 7


def test_colocated_admission_discount_unchanged():
    """The colocated discount (PR-2 behavior) still admits a prompt whose
    full blocks are live-shared when the raw need exceeds the free count."""
    shared = _prompts(1, [24], seed=22)[0]
    kw = dict(tiered=True, host_cache_blocks=16, ssd_cache_blocks=64)
    base = engine(kv_pool_blocks=64, **kw).run_continuous(
        mkreqs([shared, shared], 3), max_active=2)
    rep = engine(kv_pool_blocks=7, **kw).run_continuous(
        mkreqs([shared, shared], 3), max_active=2)
    assert rep.tokens == base.tokens
    assert max(rep.batch_trace) == 2


# ---------------------------------------------------------------------------
# planner / costmodel round-time terms
# ---------------------------------------------------------------------------

def test_decode_round_time_o1_in_active_count():
    cfg = PAPER_ARCHS["opt-66b"]
    per = [cm.decode_round_time(cfg, n, 1500, cfg.num_layers, 8, fused=False)
           for n in (1, 8, 16)]
    fus = [cm.decode_round_time(cfg, n, 1500, cfg.num_layers, 8, fused=True)
           for n in (1, 8, 16)]
    # per-seq grows linearly; fused grows only by the extra KV bytes
    assert per[1] == pytest.approx(8 * per[0])
    assert fus[1] < 2 * fus[0] and fus[2] < 2 * fus[0]
    assert per[1] / fus[1] >= 2.0
    # n=1 degenerates to the same single pass on both sides
    assert per[0] == pytest.approx(fus[0])


def test_planner_fused_round_terms_consistent():
    cfg = PAPER_ARCHS["opt-66b"]
    wl = cm.WorkloadSpec(prompt_len=1500, new_tokens=32, microbatch=8)
    p = plan(cfg, wl, 8, paged=True)
    ctx = wl.prompt_len + wl.new_tokens
    assert p.round_time_perseq_s == pytest.approx(cm.decode_round_time(
        cfg, wl.microbatch, ctx, cfg.num_layers, 64, fused=False))
    assert p.round_time_fused_s == pytest.approx(cm.decode_round_time(
        cfg, wl.microbatch, ctx, cfg.num_layers, 64, fused=True))
    assert p.fused_round_speedup == pytest.approx(
        p.round_time_perseq_s / p.round_time_fused_s)
    assert p.fused_round_speedup >= 2.0
