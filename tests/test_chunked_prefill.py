"""Chunked paged prefill + chunk-interleaved scheduling.

Exactness: chunked cold prefill and chunked prefix-adoption suffixes must be
token-identical to the batched / token-at-a-time oracle paths (the knob at 0
selects the oracles).  Speed shape: an adopted 512-token suffix completes in
ceil(512/chunk) pipeline passes instead of 512; interleaving bounds the
modeled decode stall to one chunk pass.  Planner/costmodel terms sanity.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import plan

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # optional dev dep (requirements-dev.txt)
    HAVE_HYPOTHESIS = False

CFG = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                          dtype="float32", num_layers=2)


@pytest.fixture(scope="module")
def served():
    import jax
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))

    def engine(**kw):
        return ServingEngine(CFG, model, params, 2, paged=True, **kw)

    def mkreqs(prompts, max_new=3):
        return [Request(rid=i, prompt=p.copy(), max_new=max_new)
                for i, p in enumerate(prompts)]

    return engine, mkreqs


def _prompts(n, shared, tail, seed=0):
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, CFG.vocab_size, (shared,)).astype(np.int32)
    return [np.concatenate([sysp, rng.integers(0, CFG.vocab_size,
                                               (tail,)).astype(np.int32)])
            for _ in range(n)]


# ---------------------------------------------------------------------------
# exactness: chunked paths vs the oracle paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [7, 16])        # 7 does not divide anything
def test_cold_chunked_prefill_token_identical(served, chunk):
    engine, mkreqs = served
    prompts = _prompts(2, 8, 32)                  # plen 40 > chunk
    base = engine(kv_pool_blocks=128,
                  prefill_chunk_tokens=0).run_continuous(mkreqs(prompts))
    chk = engine(kv_pool_blocks=128,
                 prefill_chunk_tokens=chunk).run_continuous(mkreqs(prompts))
    assert chk.tokens == base.tokens


def test_adopted_suffix_chunked_token_identical(served):
    """Suffix (10 tokens) chunked at 4 — the last chunk is ragged — matches
    the token-at-a-time oracle AND obeys the ceil(suffix/chunk) pass bound."""
    engine, mkreqs = served
    prompts = _prompts(3, 24, 10)
    oracle = engine(tiered=True, kv_pool_blocks=128, host_cache_blocks=16,
                    ssd_cache_blocks=64,
                    prefill_chunk_tokens=0).run_continuous(mkreqs(prompts),
                                                           max_active=1)
    eng = engine(tiered=True, kv_pool_blocks=128, host_cache_blocks=16,
                 ssd_cache_blocks=64, prefill_chunk_tokens=4)
    rep = eng.run_continuous(mkreqs(prompts), max_active=1)
    assert rep.tokens == oracle.tokens
    assert rep.prefill_tokens_saved == oracle.prefill_tokens_saved > 0
    log = eng.cluster.adoption_suffix_log
    assert log and all(p == math.ceil(s / 4) for s, p in log)


@pytest.mark.slow
def test_512_token_suffix_pass_bound():
    """Acceptance: adopting a prefix and prefilling a 512-token suffix takes
    <= ceil(512/prefill_chunk_tokens) pipeline passes (vs 512 token-at-a-time
    passes before), with token-identical output."""
    import jax
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(CFG, max_seq_len=1024)   # 520-token prompts
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    chunk = 128
    rng = np.random.default_rng(0)
    sysp = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    prompts = [np.concatenate([sysp, rng.integers(0, cfg.vocab_size,
                                                  (512,)).astype(np.int32)])
               for _ in range(2)]                  # shared first block only

    def mkreqs():
        return [Request(rid=i, prompt=p.copy(), max_new=2)
                for i, p in enumerate(prompts)]

    base = ServingEngine(cfg, model, params, 2, paged=True,
                         kv_pool_blocks=256, prefill_chunk_tokens=0)
    rb = base.run_continuous(mkreqs(), max_active=1)
    eng = ServingEngine(cfg, model, params, 2, paged=True, tiered=True,
                        kv_pool_blocks=256, host_cache_blocks=16,
                        ssd_cache_blocks=64, prefill_chunk_tokens=chunk)
    rep = eng.run_continuous(mkreqs(), max_active=1)
    assert rep.tokens == rb.tokens
    assert eng.cluster.adoption_suffix_log == [(512, math.ceil(512 / chunk))]
    assert math.ceil(512 / chunk) == 4            # vs 512 passes pre-chunking


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=5, deadline=None)
    @given(shared_blocks=st.integers(1, 3), tail=st.integers(1, 12),
           chunk=st.integers(1, 10), seed=st.integers(0, 2**31 - 1))
    def test_property_chunked_suffix_token_identical(served, shared_blocks,
                                                     tail, chunk, seed):
        """Any (prefix length, suffix length, chunk size) — including chunks
        that don't divide the suffix — yields exactly the token-at-a-time
        oracle's tokens."""
        engine, mkreqs = served
        prompts = _prompts(2, shared_blocks * CFG.kv_block_size, tail,
                           seed=seed)
        kw = dict(tiered=True, kv_pool_blocks=128, host_cache_blocks=16,
                  ssd_cache_blocks=64)
        oracle = engine(prefill_chunk_tokens=0, **kw).run_continuous(
            mkreqs(prompts, max_new=2), max_active=1)
        rep = engine(prefill_chunk_tokens=chunk, **kw).run_continuous(
            mkreqs(prompts, max_new=2), max_active=1)
        assert rep.tokens == oracle.tokens


def test_concurrent_identical_prompts_no_unwritten_sharing(served):
    """Regression: chunked prefill sizes its whole table up front, but block
    hashes must be published only as their pages are written — a second
    identical prompt admitted mid-prefill must never share/adopt (or, on
    abort, tier-demote) unwritten zero pages."""
    engine, mkreqs = served
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, CFG.vocab_size, (80,)).astype(np.int32)
    prompts = [prompt, prompt]
    base = engine(kv_pool_blocks=128, prefill_chunk_tokens=0).run_continuous(
        mkreqs(prompts, max_new=4), max_active=2)
    chk = engine(kv_pool_blocks=128, prefill_chunk_tokens=16).run_continuous(
        mkreqs(prompts, max_new=4), max_active=2)
    assert chk.tokens == base.tokens
    tier = engine(tiered=True, kv_pool_blocks=128, host_cache_blocks=16,
                  ssd_cache_blocks=64, prefill_chunk_tokens=16)
    rt = tier.run_continuous(mkreqs(prompts, max_new=4), max_active=2)
    assert rt.tokens == base.tokens
    # the co-admitted request adopted only blocks already written when it
    # arrived — strictly fewer than the full 72-token adoptable prefix
    assert 0 < rt.prefill_tokens_saved <= 72


# ---------------------------------------------------------------------------
# chunk-interleaved scheduling bounds the per-round decode stall
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_interleaving_bounds_decode_stall(served):
    """A long prompt admitted next to short decoding requests: without
    chunking it stalls a decode round by its whole prefill; interleaved, the
    worst round waits one chunk and prefill spreads over several rounds."""
    engine, mkreqs = served
    rng = np.random.default_rng(3)
    short = [rng.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
             for _ in range(2)]
    long_p = rng.integers(0, CFG.vocab_size, (96,)).astype(np.int32)
    prompts = short + [long_p]
    base = engine(kv_pool_blocks=128, prefill_chunk_tokens=0).run_continuous(
        mkreqs(prompts, max_new=8), max_active=3)
    chk = engine(kv_pool_blocks=128, prefill_chunk_tokens=16).run_continuous(
        mkreqs(prompts, max_new=8), max_active=3)
    assert chk.tokens == base.tokens
    assert max(chk.prefill_stall_trace) < max(base.prefill_stall_trace)
    # the prompt's passes spread over multiple decode rounds
    assert sum(1 for s in chk.prefill_stall_trace if s > 0) \
        > sum(1 for s in base.prefill_stall_trace if s > 0)


@pytest.mark.slow
def test_failure_mid_chunked_prefill_recovers(served):
    """A worker dies while a chunked prefill is in flight: the in-flight
    prefill aborts (its partial tables died with the worker), restarts on
    the recovered cluster — still on the fast chunked path — and the trace
    regenerates bit-identically."""
    engine, mkreqs = served
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, CFG.vocab_size, (8,)).astype(np.int32),
               rng.integers(0, CFG.vocab_size, (80,)).astype(np.int32)]
    base = engine(kv_pool_blocks=128, prefill_chunk_tokens=16,
                  replication=True).run_continuous(mkreqs(prompts, max_new=6),
                                                   max_active=2)
    for g in (3, 5):                     # gsteps landing mid-prefill of rid 1
        eng = engine(kv_pool_blocks=128, prefill_chunk_tokens=16,
                     replication=True)
        rep = eng.run_continuous(mkreqs(prompts, max_new=6), max_active=2,
                                 fail_at={g: 1})
        assert rep.failures == 1 and rep.recoveries == 1
        assert rep.tokens == base.tokens


# ---------------------------------------------------------------------------
# costmodel / planner terms
# ---------------------------------------------------------------------------

def test_chunked_prefill_time_terms():
    from repro.core.dejavulib.transport import DEFAULT_HW
    cfg = PAPER_ARCHS["opt-66b"]
    one_pass = cm.chunked_prefill_time(cfg, 512, 0, cfg.num_layers, 8)
    chunked = cm.chunked_prefill_time(cfg, 512, 64, cfg.num_layers, 8)
    # exact causal accounting: the chunked FLOPs equal the one-pass FLOPs
    # regardless of chunking — the ONLY overhead is per-pass dispatch latency
    assert chunked == pytest.approx(one_pass + 7 * DEFAULT_HW.net_latency)
    assert chunked >= one_pass > 0
    # one pass over a chunk is much shorter than over the whole prompt
    pass_chunk = cm.chunked_prefill_pass_time(cfg, 64, 512, cfg.num_layers, 8)
    pass_full = cm.chunked_prefill_pass_time(cfg, 512, 512, cfg.num_layers, 8)
    assert pass_chunk < pass_full / 4


def test_planner_decode_stall_shrinks_with_chunking():
    cfg = PAPER_ARCHS["opt-66b"]
    wl = cm.WorkloadSpec(prompt_len=3000, new_tokens=32, microbatch=8)
    base = plan(cfg, wl, 8, paged=True)
    chk = plan(cfg, wl, 8, paged=True, prefill_chunk_tokens=128)
    assert base.feasible and chk.feasible
    assert 0 < chk.decode_stall_s < base.decode_stall_s
    assert 0 < chk.bubble_frac < base.bubble_frac < 1
    # the two reported fields are mutually consistent: bubble_frac is
    # derived from the SAME stall decode_stall_s reports
    for p in (base, chk):
        assert p.decode_stall_s == pytest.approx(
            cm.prefill_stall_time(cfg, wl,
                                  128 if p is chk else 0,
                                  cfg.num_layers, 64))
        t = cm.stage_token_time(cfg, wl, cfg.num_layers, 64,
                                wl.prompt_len + wl.new_tokens)
        assert p.bubble_frac == pytest.approx(
            p.decode_stall_s / (p.decode_stall_s + t))
    # chunking the prompt does not change the throughput plan itself
    assert chk.inv_tp_disagg == base.inv_tp_disagg
