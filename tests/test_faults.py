"""Unit tests for the deterministic fault-injection layer
(`repro.core.dejavulib.faults`) and the StreamEngine hardening that rides
with it: background-error surfacing, post-close submit rejection, transport
drop/corrupt-then-retry, SSD crash-mid-write atomicity, and the engine's
`fail_at` → FaultPlan shim with `EngineReport.fault_trace`.

The exhaustive per-mode crash-consistency sweep lives in
tests/test_crash_consistency.py (slow).
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs.registry import PAPER_ARCHS
from repro.core.dejavulib import faults
from repro.core.dejavulib.buffers import SSDStore
from repro.core.dejavulib.faults import (FaultInjected, FaultInjector,
                                         FaultPlan, FaultSpec, StreamTaskError)
from repro.core.dejavulib.streamer import StreamEngine
from repro.core.dejavulib.transport import LocalTransport, NetworkTransport
from repro.models import build_model
from repro.serving import Request, ServingEngine


# ---------------------------------------------------------------------------
# FaultPlan / FaultInjector mechanics
# ---------------------------------------------------------------------------

def test_injector_counts_points_independently():
    inj = FaultInjector(record=True)
    with faults.active(inj):
        faults.fire("a", tag="x")
        faults.fire("b")
        faults.fire("a", tag="y")
    assert inj.counts == {"a": 2, "b": 1}
    assert inj.trace == [("a", 1, "x"), ("b", 1, ""), ("a", 2, "y")]
    assert inj.fired == []


def test_plan_targets_nth_occurrence_only():
    plan = FaultPlan([FaultSpec("p", nth=2, kind="error")])
    inj = FaultInjector(plan)
    with faults.active(inj):
        assert faults.fire("p") is None           # 1st: clean
        with pytest.raises(FaultInjected) as ei:
            faults.fire("p")                      # 2nd: boom
        assert ei.value.n == 2 and ei.value.point == "p"
        assert faults.fire("p") is None           # 3rd: clean again
    assert [f.n for f in inj.fired] == [2]


def test_spec_times_window_matches_consecutive_occurrences():
    plan = FaultPlan([FaultSpec("p", nth=2, kind="delay", delay_s=0.5,
                                times=2)])
    inj = FaultInjector(plan)
    with faults.active(inj):
        got = [faults.fire("p") for _ in range(4)]
    assert [g.kind if g else None for g in got] == [None, "delay", "delay",
                                                   None]


def test_no_injector_installed_is_a_noop():
    assert faults.current() is None
    assert faults.fire("anything") is None


def test_site_kinds_return_spec_instead_of_raising():
    plan = FaultPlan([FaultSpec("p", nth=1, kind="drop")])
    with faults.active(FaultInjector(plan)):
        spec = faults.fire("p")
    assert spec.kind == "drop"


def test_worker_death_without_killer_raises():
    plan = FaultPlan([FaultSpec("p", nth=1, kind="worker_death", wid=0)])
    with faults.active(FaultInjector(plan)):
        with pytest.raises(FaultInjected):
            faults.fire("p")


def test_worker_death_calls_bound_killer():
    killed = []
    plan = FaultPlan([FaultSpec("p", nth=1, kind="worker_death", wid=3)])
    inj = FaultInjector(plan)
    inj.worker_killer = killed.append
    with faults.active(inj):
        assert faults.fire("p") is None
    assert killed == [3]
    assert inj.fired[0].wid == 3


def test_from_fail_at_shim_builds_engine_step_specs():
    plan = FaultPlan.from_fail_at({9: 2, 5: 0})
    assert [(s.nth, s.wid) for s in plan.specs] == [(5, 0), (9, 2)]
    assert all(s.point == "engine.step" and s.kind == "worker_death"
               for s in plan.specs)


def test_spec_validation_rejects_bad_kinds_and_counts():
    with pytest.raises(ValueError):
        FaultSpec("p", nth=1, kind="nope")
    with pytest.raises(ValueError):
        FaultSpec("p", nth=0)
    with pytest.raises(ValueError):
        FaultSpec("p", nth=1, kind="worker_death")   # no wid


# ---------------------------------------------------------------------------
# StreamEngine hardening (satellites: background errors, close semantics)
# ---------------------------------------------------------------------------

def test_background_error_surfaces_on_drain():
    eng = StreamEngine("bg-drain")
    eng.submit(lambda: 1 / 0, tag="boom")        # fire-and-forget
    with pytest.raises(StreamTaskError) as ei:
        eng.drain()
    assert isinstance(ei.value.__cause__, ZeroDivisionError)
    assert "boom" in str(ei.value)
    eng.drain()                                  # consumed: clean barrier
    eng.close()


def test_background_error_surfaces_on_close():
    eng = StreamEngine("bg-close")
    eng.submit(lambda: 1 / 0, tag="boom")
    with pytest.raises(StreamTaskError):
        eng.close()
    assert not eng._thread.is_alive()


def test_waited_error_is_not_double_reported():
    eng = StreamEngine("bg-wait")
    t = eng.submit(lambda: 1 / 0, tag="boom")
    with pytest.raises(ZeroDivisionError):
        eng.wait(t)
    eng.drain()                                  # caller handled it: clean
    eng.close()


def test_submit_after_close_is_rejected():
    eng = StreamEngine("closing")
    eng.close()
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(lambda: None, tag="late")
    eng.close()                                  # idempotent
    assert not eng._thread.is_alive()


def test_injected_task_error_is_retried_once():
    plan = FaultPlan([FaultSpec("stream.task", nth=1, kind="task_error")])
    inj = FaultInjector(plan)
    ran = []
    with faults.active(inj):
        eng = StreamEngine("retry")
        t = eng.submit(lambda: ran.append(1) or "ok", tag="job")
        assert eng.wait(t, timeout=5) == "ok"
        eng.drain()                              # no background error kept
        eng.close()
    assert ran == [1]                            # fault hit before fn ran
    assert [f.kind for f in inj.fired] == ["task_error"]


def test_injected_hard_error_is_not_retried():
    plan = FaultPlan([FaultSpec("stream.task", nth=1, kind="error")])
    with faults.active(FaultInjector(plan)):
        eng = StreamEngine("hard")
        t = eng.submit(lambda: "ok", tag="job")
        with pytest.raises(FaultInjected):
            eng.wait(t, timeout=5)
        eng.close()


def test_injected_submit_delay_charges_model_time():
    plan = FaultPlan([FaultSpec("stream.submit", nth=1, kind="delay",
                                delay_s=2.5)])
    with faults.active(FaultInjector(plan)):
        eng = StreamEngine("late")
        eng.submit(lambda: None, model_seconds=1.0, tag="job")
        eng.drain()
    assert eng.overlap_report()["stream_s"] == pytest.approx(3.5)
    eng.close()


# ---------------------------------------------------------------------------
# Transport faults: drop / corrupt are detected and retransmitted
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["drop", "corrupt"])
def test_transport_fault_retransmits_exact_bytes(kind):
    tr = LocalTransport()
    plan = FaultPlan([FaultSpec("transport.transfer.local", nth=2, kind=kind)])
    src = np.arange(32, dtype=np.float32)
    with faults.active(FaultInjector(plan)) as inj:
        a1 = tr.transfer(src, tag="t1")
        a2 = tr.transfer(src, tag="t2")
    np.testing.assert_array_equal(a1, src)
    np.testing.assert_array_equal(a2, src)       # exact despite the fault
    assert [f.kind for f in inj.fired] == [kind]
    # the retransmission is charged to the modeled timeline and tagged
    assert tr.log[1].model_seconds == pytest.approx(2 * tr.log[0].model_seconds)
    assert tr.log[1].tag == f"t2+retry({kind})"
    assert tr.log[0].tag == "t1"


def test_transport_delay_charges_straggler_time():
    tr = NetworkTransport()
    plan = FaultPlan([FaultSpec("transport.transfer.net", nth=1, kind="delay",
                                delay_s=7.0)])
    src = np.ones(4, np.float32)
    with faults.active(FaultInjector(plan)):
        out = tr.transfer(src, tag="slow")
    np.testing.assert_array_equal(out, src)
    base = tr.model_time(src.nbytes)
    assert tr.log[0].model_seconds == pytest.approx(base + 7.0)


def test_transport_points_are_per_link_kind():
    """A plan aimed at the net link must not perturb hostlink traffic."""
    net, loc = NetworkTransport(), LocalTransport()
    plan = FaultPlan([FaultSpec("transport.transfer.net", nth=1, kind="drop")])
    with faults.active(FaultInjector(plan)) as inj:
        loc.transfer(np.ones(4), tag="l")
        net.transfer(np.ones(4), tag="n")
    assert inj.counts == {"transport.transfer.local": 1,
                          "transport.transfer.net": 1}
    assert loc.log[0].tag == "l"                 # untouched
    assert net.log[0].tag == "n+retry(drop)"


# ---------------------------------------------------------------------------
# SSD crash-mid-write (satellite): old block or none, never torn
# ---------------------------------------------------------------------------

def test_ssd_crash_mid_write_leaves_old_block(tmp_path):
    store = SSDStore(str(tmp_path), name="crashy")
    old = np.arange(64, dtype=np.float32).reshape(8, 8)
    store.put("pfx/1", old)
    plan = FaultPlan([FaultSpec("ssd.put", nth=1, kind="ssd_write")])
    with faults.active(FaultInjector(plan)):
        with pytest.raises(FaultInjected):
            store.put("pfx/1", np.zeros((16, 16), np.float32))
    # a NEW handle (fresh process after the crash) sees the old bytes intact
    np.testing.assert_array_equal(SSDStore(str(tmp_path)).get("pfx/1"), old)
    assert store.size("pfx/1") > 0


def test_ssd_crash_mid_write_fresh_key_sees_none(tmp_path):
    store = SSDStore(str(tmp_path), name="crashy")
    plan = FaultPlan([FaultSpec("ssd.put", nth=1, kind="ssd_write")])
    with faults.active(FaultInjector(plan)):
        with pytest.raises(FaultInjected):
            store.put("pfx/2", np.ones(4))
    assert "pfx/2" not in store
    assert SSDStore(str(tmp_path)).keys() == []
    # the fsync'd temp file was cleaned up, not leaked
    assert [f for f in os.listdir(tmp_path) if ".tmp." in f] == []


def test_ssd_put_succeeds_after_transient_fault_window(tmp_path):
    """The same key writes cleanly once the faulted occurrence has passed —
    the crash left no state that blocks a retry (what the stream worker's
    retry path relies on)."""
    store = SSDStore(str(tmp_path))
    plan = FaultPlan([FaultSpec("ssd.put", nth=1, kind="ssd_write")])
    arr = np.full(8, 7.0)
    with faults.active(FaultInjector(plan)):
        with pytest.raises(FaultInjected):
            store.put("k", arr)
        store.put("k", arr)                      # retry: counter advanced
    np.testing.assert_array_equal(store.get("k"), arr)


# ---------------------------------------------------------------------------
# Engine integration: fail_at shim ≡ FaultPlan, fault_trace populated
# ---------------------------------------------------------------------------

CFG = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                          dtype="float32", num_layers=4)
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
RNG = np.random.default_rng(0)
PROMPTS = RNG.integers(0, CFG.vocab_size, (2, 8)).astype(np.int32)
N_NEW = 4


def _mkreqs():
    return [Request(rid=i, prompt=PROMPTS[i].copy(), max_new=N_NEW)
            for i in range(2)]


def _engine(**kw):
    kw.setdefault("paged", True)
    kw.setdefault("replication", True)
    return ServingEngine(CFG, MODEL, PARAMS, 2, mode="colocated",
                         microbatch=1, **kw)


@pytest.fixture(scope="module")
def baseline_tokens():
    rep = _engine().run_continuous(_mkreqs(), max_active=2)
    return rep.tokens


def test_fail_at_shim_recovers_token_identical(baseline_tokens):
    rep = _engine().run_continuous(_mkreqs(), max_active=2, fail_at={4: 1})
    assert rep.failures == 1 and rep.recoveries == 1
    assert rep.tokens == baseline_tokens
    assert rep.fault_trace == [
        {"point": "engine.step", "n": 4, "kind": "worker_death",
         "tag": rep.fault_trace[0]["tag"], "wid": 1}]


def test_fault_plan_equivalent_to_fail_at(baseline_tokens):
    plan = FaultPlan([FaultSpec("engine.step", nth=4, kind="worker_death",
                                wid=1)])
    rep = _engine().run_continuous(_mkreqs(), max_active=2, fault_plan=plan)
    assert rep.failures == 1 and rep.recoveries == 1
    assert rep.tokens == baseline_tokens


def test_clean_run_leaves_no_fault_state(baseline_tokens):
    eng = _engine()
    rep = eng.run_continuous(_mkreqs(), max_active=2)
    assert rep.fault_trace == [] and rep.failures == 0
    assert faults.current() is None
    faults.assert_no_leaks(eng.cluster)


def test_injector_records_reference_trace(baseline_tokens):
    inj = FaultInjector(record=True)
    eng = _engine()
    rep = eng.run_continuous(_mkreqs(), max_active=2, fault_injector=inj)
    assert rep.tokens == baseline_tokens
    assert faults.current() is None              # uninstalled after the run
    assert inj.counts.get("engine.step", 0) > 0
    assert inj.counts.get("stream.drain", 0) > 0        # replication barriers
    assert inj.counts.get("transport.transfer.net", 0) > 0
    # the trace is replayable: every (point, n) is unique and ordered
    per_point = {}
    for point, n, _tag in inj.trace:
        assert n == per_point.get(point, 0) + 1
        per_point[point] = n
    assert per_point == inj.counts
