"""Paged KV pool: allocator invariants (fuzz + hypothesis), prefix sharing,
copy-on-write, defrag, paged-attention kernel vs the dense reference, and
paged-aware planning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import costmodel as cm
from repro.core.planner import MachineSpec, min_token_depth, plan
from repro.kernels import ref
from repro.kernels.decode_attention import paged_decode_attention
from repro.kvcache.paged import (BlockPool, PagedKVCache, PoolExhausted,
                                 blocks_for)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # optional dev dep (requirements-dev.txt)
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------

def _check_invariants(pool: BlockPool):
    free = set(pool._free)
    multiplicity = {}
    for table in pool.tables.values():
        for bid in table:
            multiplicity[bid] = multiplicity.get(bid, 0) + 1
    # free XOR referenced, never both; ref count == table multiplicity
    assert not (free & set(multiplicity)), "block both free and referenced"
    for bid, blk in enumerate(pool.blocks):
        assert blk.ref == multiplicity.get(bid, 0)
        assert (bid in free) == (blk.ref == 0)
    assert len(free) + sum(1 for b in pool.blocks if b.ref > 0) == pool.num_blocks


def _run_ops(num_blocks, block_size, ops):
    """Interpret an op tape against a pool; ops are (kind, seq, arg)."""
    pool = BlockPool(num_blocks, block_size)
    live = set()
    for kind, seq, arg in ops:
        try:
            if kind == "alloc" and seq not in live:
                pool.allocate(seq, arg % (num_blocks * block_size) + 1,
                              token_ids=list(range(arg % 40)) if arg % 2 else None)
                live.add(seq)
            elif kind == "append" and seq in live:
                pool.append(seq, 1 + arg % 3)
            elif kind == "free" and seq in live:
                pool.free_seq(seq)
                live.discard(seq)
            elif kind == "truncate" and seq in live:
                pool.truncate(seq, max(1, pool.seq_lens[seq] - arg % 5))
        except PoolExhausted:
            pass                     # legal outcome under a random tape
        _check_invariants(pool)
    for seq in list(live):
        pool.free_seq(seq)
    _check_invariants(pool)
    assert pool.num_free() == pool.num_blocks, "leak: blocks not returned"


def test_fuzz_alloc_free_never_leaks():
    rng = np.random.default_rng(0)
    kinds = ["alloc", "append", "append", "free", "truncate"]
    for trial in range(15):
        ops = [(kinds[rng.integers(len(kinds))], int(rng.integers(6)),
                int(rng.integers(64))) for _ in range(60)]
        _run_ops(int(rng.integers(4, 24)), int(rng.integers(2, 9)), ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(num_blocks=st.integers(2, 32), block_size=st.integers(1, 8),
           ops=st.lists(st.tuples(
               st.sampled_from(["alloc", "append", "free", "truncate"]),
               st.integers(0, 5), st.integers(0, 63)), max_size=40))
    def test_property_no_double_alloc_no_leak(num_blocks, block_size, ops):
        _run_ops(num_blocks, block_size, ops)


def test_pool_exhaustion_raises():
    pool = BlockPool(2, 4)
    pool.allocate(0, 8)
    with pytest.raises(PoolExhausted):
        pool.allocate(1, 1)
    assert not pool.can_allocate(1) and pool.can_allocate(0)


def test_prefix_sharing_and_copy_on_write():
    pool = BlockPool(16, 4)
    toks = list(range(10))
    t1, fresh1 = pool.allocate(1, 10, token_ids=toks)
    t2, fresh2 = pool.allocate(2, 10, token_ids=toks)
    assert t1[:2] == t2[:2] and t1[2] != t2[2]     # full blocks shared
    assert fresh1 == [0, 1, 2] and fresh2 == [2]
    assert pool.blocks[t1[0]].ref == 2
    # seq 2 appends into its own partial block: no CoW needed
    assert pool.append(2) == []
    # force CoW: a sequence ending exactly on a shared full block
    t3, _ = pool.allocate(3, 8, token_ids=toks[:8])
    assert t3 == t1[:2]
    cow = pool.append(3)               # grows into a NEW block, no divergence
    assert cow == [] and len(pool.tables[3]) == 3
    pool.free_seq(1); pool.free_seq(2); pool.free_seq(3)
    assert pool.num_free() == pool.num_blocks


def test_cow_on_shared_partial_block():
    # sharing a partial tail can only arise via append over a shared FULL
    # block boundary; emulate divergence by ref-bumping then appending
    pool = BlockPool(8, 4)
    pool.allocate(1, 4, token_ids=list(range(4)))
    t2, _ = pool.allocate(2, 4, token_ids=list(range(4)))
    pool.truncate(2, 3)                # seq 2 now ends INSIDE the shared block
    cow = pool.append(2)
    assert len(cow) == 1               # diverged: copy-on-write
    old, new = cow[0]
    assert pool.tables[2] == [new] and pool.tables[1] == [old]
    pool.free_seq(1); pool.free_seq(2)
    assert pool.num_free() == pool.num_blocks


def test_defrag_compacts_and_preserves_pages():
    pool = BlockPool(16, 4)
    pages = PagedKVCache(pool, layers=2, num_kv_heads=2, head_dim=4)
    t1, _ = pool.allocate(1, 8)
    t2, _ = pool.allocate(2, 6)
    pages.k[t1] = 1.0
    pages.k[t2] = 2.0
    pool.free_seq(1)
    moves = pool.defrag()
    pages.apply_defrag(moves)
    _check_invariants(pool)
    assert pool.tables[2] == [0, 1]               # compacted to lowest ids
    dense = pages.gather_dense(2, 8)
    assert (dense["k"][:, :, :6] == 2.0).all()


def test_write_window_gather_roundtrip():
    pool = BlockPool(8, 4)
    pages = PagedKVCache(pool, layers=3, num_kv_heads=2, head_dim=4)
    pool.allocate(7, 10)
    rng = np.random.default_rng(0)
    win = {leaf: rng.standard_normal((3, 10, 2, 4)).astype(np.float32)
           for leaf in ("k", "v")}
    pages.write_window(7, win, 0)
    dense = pages.gather_dense(7, 12)
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(dense[leaf][:, 0, :10], win[leaf])
        assert (dense[leaf][:, 0, 10:] == 0).all()


# ---------------------------------------------------------------------------
# paged-attention decode kernel vs references
# ---------------------------------------------------------------------------

def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,d,bs,lens", [
    (3, 8, 2, 16, 8, (5, 17, 24)),          # odd lengths, GQA
    (2, 4, 4, 32, 16, (1, 31)),             # MHA, length-1 edge
    (1, 6, 2, 64, 4, (13,)),                # tiny blocks
    (4, 8, 1, 16, 8, (8, 16, 9, 3)),        # MQA, block-aligned + odd
])
def test_paged_decode_matches_dense_reference(b, hq, hkv, d, bs, lens, dtype):
    n_pages = 48
    lens = np.asarray(lens, np.int32)
    mx = int(max(-(-lens // bs)))
    rng = np.random.default_rng(0)
    perm = list(rng.permutation(n_pages))
    tables = np.zeros((b, mx), np.int32)
    for i, L in enumerate(lens):
        for j in range(-(-int(L) // bs)):
            tables[i, j] = perm.pop()
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kp = jax.random.normal(ks[1], (n_pages, bs, hkv, d), dtype)
    vp = jax.random.normal(ks[2], (n_pages, bs, hkv, d), dtype)
    out = paged_decode_attention(q, kp, vp, tables, lens)
    # vs paged oracle
    expect = ref.paged_decode_attention_ref(q, kp, vp, jnp.asarray(tables),
                                            jnp.asarray(lens))
    err = np.max(np.abs(np.asarray(out, np.float32)
                        - np.asarray(expect, np.float32)))
    assert err < _tol(dtype), err
    # vs the DENSE reference per sequence (gather pages -> contiguous cache)
    for i in range(b):
        kd = ref.paged_gather_ref(kp, jnp.asarray(tables[i:i + 1]))
        vd = ref.paged_gather_ref(vp, jnp.asarray(tables[i:i + 1]))
        valid = jnp.arange(kd.shape[1]) < int(lens[i])
        dense = ref.decode_attention_ref(q[i:i + 1], kd, vd, valid)
        err = np.max(np.abs(np.asarray(out[i:i + 1], np.float32)
                            - np.asarray(dense, np.float32)))
        assert err < _tol(dtype), (i, err)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(b=st.integers(1, 4), bs=st.sampled_from([4, 8]),
           seed=st.integers(0, 100))
    def test_property_paged_decode_matches_reference(b, bs, seed):
        hq, hkv, d, n_pages = 4, 2, 16, 32
        rng = np.random.default_rng(seed)
        lens = rng.integers(1, 3 * bs, size=b).astype(np.int32)
        mx = int(max(-(-lens // bs)))
        perm = list(rng.permutation(n_pages))
        tables = np.zeros((b, mx), np.int32)
        for i, L in enumerate(lens):
            for j in range(-(-int(L) // bs)):
                tables[i, j] = perm.pop()
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(k1, (b, hq, d), jnp.float32)
        kp = jax.random.normal(k2, (n_pages, bs, hkv, d), jnp.float32)
        vp = jax.random.normal(k3, (n_pages, bs, hkv, d), jnp.float32)
        out = paged_decode_attention(q, kp, vp, tables, lens)
        expect = ref.paged_decode_attention_ref(q, kp, vp, jnp.asarray(tables),
                                                jnp.asarray(lens))
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# planner: paged accounting
# ---------------------------------------------------------------------------

def test_blocks_for():
    assert blocks_for(0, 8) == 0 and blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1 and blocks_for(9, 8) == 2


def test_paged_state_bytes_rounds_to_blocks():
    cfg = get_arch("opt-66b")
    assert cfg.paged_state_bytes(9) == cfg.decode_state_bytes(16)
    assert cfg.paged_state_bytes(9) < cfg.decode_state_bytes(1220)


def test_planner_paged_needs_no_more_memory_than_static():
    cfg = get_arch("opt-66b")
    mach = MachineSpec()
    wl = cm.WorkloadSpec(prompt_len=1000, new_tokens=220, microbatch=16)
    dt_static = min_token_depth(cfg, wl, mach)
    dt_paged = min_token_depth(cfg, wl, mach, paged=True)
    assert dt_static > 0 and 0 < dt_paged <= dt_static
    # a generation-heavy workload that is static-infeasible (the full
    # prompt+new reservation per request overflows every split) becomes
    # feasible when the planner accounts live blocks only
    wl_gen = cm.WorkloadSpec(prompt_len=200, new_tokens=1500, microbatch=32)
    assert min_token_depth(cfg, wl_gen, mach) == -1        # static: never fits
    assert min_token_depth(cfg, wl_gen, mach, paged=True) > 0
    assert not plan(cfg, wl_gen, 6, mach).feasible
    p_paged = plan(cfg, wl_gen, 6, mach, paged=True)
    assert p_paged.feasible and p_paged.d_prompt + p_paged.d_token == 6
