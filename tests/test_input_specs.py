"""Deliverable-(e/f) surface: input_specs() must be well-formed for every
(arch × shape) cell — ShapeDtypeStructs only (no allocation), shapes
consistent with the config and the decode-state layout.  eval_shape-based,
so the full 40-cell matrix checks in seconds."""
import jax
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, supports_shape
from repro.kvcache.cache import decode_state_shapes
from repro.launch.specs import input_specs
from repro.models import build_model

pytestmark = pytest.mark.slow  # full sweep; excluded from `pytest -m "not slow"`

CELLS = [(a, s) for a in sorted(ARCHS) for s in SHAPES]


@pytest.mark.parametrize("arch,shape", CELLS)
def test_input_specs_cover_every_cell(arch, shape):
    cfg = get_arch(arch)
    sh = SHAPES[shape]
    ok, reason = supports_shape(cfg, sh)
    if not ok:
        assert "sub-quadratic" in reason
        return
    model = build_model(cfg)
    specs = input_specs(cfg, sh, model)
    # every leaf is a ShapeDtypeStruct — nothing allocated
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    assert "params" in specs
    if sh.kind == "train":
        assert specs["batch"]["tokens"].shape[0] == sh.global_batch
        assert "opt_state" in specs
        # optimizer moments mirror the param tree
        n_p = len(jax.tree.leaves(specs["params"]))
        n_m = len(jax.tree.leaves(specs["opt_state"].m))
        assert n_p == n_m
    elif sh.kind == "prefill":
        toks = specs["batch"]["tokens"]
        assert toks.shape[0] == sh.global_batch
        assert toks.shape[1] + cfg.context_overhead == sh.seq_len or \
            cfg.family == "encdec"
    else:  # decode
        assert specs["token"].shape == (sh.global_batch,)
        # state specs match the canonical decode-state layout exactly
        want = decode_state_shapes(cfg, sh.global_batch, sh.seq_len)

        def flatten(d, pre=""):
            out = {}
            for k, v in d.items():
                if isinstance(v, dict):
                    out.update(flatten(v, pre + k + "/"))
                else:
                    out[pre + k] = v
            return out

        got = flatten(specs["state"])
        expect = flatten(want)
        assert set(got) == set(expect)
        for k in got:
            assert tuple(got[k].shape) == tuple(expect[k][0]), k
