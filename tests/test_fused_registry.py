"""Registry-wide fused-round guard.

`fused_rounds` defaults ON, so EVERY config in `repro.configs.registry`
must either (a) pass the `fused_ok` gate and decode token-identically to
the per-sequence oracle path, or (b) fail the gate and fall back cleanly
(no crash, per-sequence pass shape).  This sweep pins the gate's verdict
per family so a new config or a gate edit cannot silently fuse an
unsupported architecture — or silently stop fusing a supported one.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS, PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.cluster import fused_supported
from repro.models import build_model
from repro.serving import Request, ServingEngine

ALL = {**ARCHS, **PAPER_ARCHS}
# serving (cluster/worker stage APIs) is DecoderLM-only: dense + moe run the
# real engine; the other families are gate-level assertions only
SERVABLE = ("dense", "moe")


def _reduced(cfg):
    return dataclasses.replace(cfg.reduced(), dtype="float32")


def _reqs(cfg, n=3, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, (6 + 2 * (i % 2),)
                            ).astype(np.int32) for i in range(n)]
    return [Request(rid=i, prompt=p, max_new=3)
            for i, p in enumerate(prompts)]


def test_registry_gate_verdict_matches_family():
    """The gate is a family property: dense/moe fuse, everything else
    (ssm/hybrid recurrent state, encdec cross-attention, vlm patch
    positions) must not — and the costmodel mirror must agree so planner
    round terms degrade to the per-sequence time for unfusable configs."""
    for name, cfg in ALL.items():
        expect = cfg.family in SERVABLE and not cfg.num_patches
        assert fused_supported(cfg) is expect, name
        assert cm.fused_round_supported(cfg) is expect, name
        if not expect:
            ctx = 256
            per = cm.decode_round_time(cfg, 8, ctx, cfg.num_layers, 8,
                                       fused=False)
            fus = cm.decode_round_time(cfg, 8, ctx, cfg.num_layers, 8,
                                       fused=True)
            assert fus == pytest.approx(per), name


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(n for n, c in ALL.items()
                                        if c.family in SERVABLE
                                        and not c.num_patches))
def test_registry_fused_identity(name):
    """Every servable registry config — RoPE, learned-position, ALiBi,
    GQA/MHA, MoE — decodes token-identically fused vs per-sequence, and the
    default engine really takes the fused path (fewer pipeline passes)."""
    cfg = _reduced(ALL[name])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = ServingEngine(cfg, model, params, 2, paged=True, kv_pool_blocks=64,
                         fused_rounds=False).run_continuous(
        _reqs(cfg), max_active=3)
    eng = ServingEngine(cfg, model, params, 2, paged=True, kv_pool_blocks=64)
    assert eng.cluster.fused_ok is True, name
    rep = eng.run_continuous(_reqs(cfg), max_active=3)
    assert rep.tokens == base.tokens, name
    assert sum(rep.pass_trace) < sum(base.pass_trace), name


def test_registry_vlm_falls_back_cleanly():
    """phi-3-vision builds a DecoderLM but carries patch positions the
    batched path does not model: with the default knob ON the engine's gate
    must still choose the per-sequence path (identical pass shape)."""
    cfg = _reduced(ALL["phi-3-vision-4.2b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, model, params, 2, paged=True, kv_pool_blocks=64)
    assert eng.cluster.fused_ok is False
