"""Sharding rules + a tiny-mesh jit of reduced models under those rules."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, supports_shape
from repro.distributed.sharding import (batch_shardings, fsdp_enabled,
                                        param_shardings, state_shardings)
from repro.kvcache.cache import decode_state_shapes
from repro.launch.mesh import make_local_mesh
from repro.models import build_model


def test_fsdp_threshold():
    assert fsdp_enabled(get_arch("nemotron-4-340b"))
    assert fsdp_enabled(get_arch("yi-34b"))
    assert not fsdp_enabled(get_arch("smollm-360m"))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_shardings_cover_every_leaf(name):
    cfg = get_arch(name)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    mesh = make_local_mesh()
    sh = param_shardings(shapes, cfg, mesh)
    n_shapes = len(jax.tree.leaves(shapes))
    n_sh = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_shapes == n_sh


def test_state_shardings_long_context_batch1():
    cfg = get_arch("hymba-1.5b")
    mesh = make_local_mesh()
    shapes = decode_state_shapes(cfg, 1, 4096)
    sh = state_shardings(shapes, cfg, mesh, batch=1)
    assert len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))) == \
        len(jax.tree.leaves(shapes, is_leaf=lambda x: isinstance(x, tuple)))


def test_jit_under_local_mesh_with_rules():
    """End-to-end: shard a reduced model's params per the rules on a 1x1 mesh
    named like production and run a loss step."""
    cfg = dataclasses.replace(get_arch("internlm2-1.8b").reduced(), dtype="float32")
    model = build_model(cfg)
    mesh = make_local_mesh()
    params = model.init(jax.random.PRNGKey(0))
    shapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    sh = param_shardings(shapes, cfg, mesh)
    params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, sh)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "targets": jnp.zeros((2, 16), jnp.int32),
             "loss_mask": jnp.ones((2, 16), jnp.float32)}
    b_sh = batch_shardings(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch),
        cfg, mesh)
    # jax >= 0.5 wants an explicit mesh context; 0.4.x has no jax.set_mesh and
    # NamedSharding already carries the mesh, so the context is optional
    import contextlib
    set_mesh = getattr(jax, "set_mesh", None)
    ctx = set_mesh(mesh) if set_mesh is not None else contextlib.nullcontext()
    with ctx:
        loss = jax.jit(model.loss, in_shardings=(sh, b_sh))(params, batch)
    assert bool(jnp.isfinite(loss))


def test_supports_shape_matrix():
    """Exactly the 8 pure-attention archs skip long_500k (32 runnable cells)."""
    runnable = skipped = 0
    for name in ARCHS:
        for sname, shape in SHAPES.items():
            ok, reason = supports_shape(get_arch(name), shape)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert sname == "long_500k"
                assert get_arch(name).family not in ("ssm", "hybrid")
    assert runnable == 32 and skipped == 8      # 40 total cells
