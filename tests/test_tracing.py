"""Flight recorder (repro.core.tracing): ring-buffer semantics, causal
span nesting, cross-thread routing, byte-identical dumps across identical
serving runs, the three wire-format exporters, and the trace_report
critical-path gate."""
import dataclasses
import importlib.util
import json
import os
import threading

import numpy as np
import pytest

from repro.core import exporters, telemetry, tracing
from repro.core.dejavulib import faults

_TOOL = os.path.join(os.path.dirname(__file__), "..", "tools",
                     "trace_report.py")
_spec = importlib.util.spec_from_file_location("trace_report", _TOOL)
trace_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(trace_report)


# ---------------------------------------------------------------------------
# unit level: ring buffer, spans, thread routing
# ---------------------------------------------------------------------------

def test_ring_overwrites_oldest_and_counts_drops():
    t = tracing.Tracer(capacity=4)
    for i in range(10):
        t.event("e", n=i)
    tr = t.snapshot()["tracks"][tracing.SERVE_TRACK]
    assert tr["emitted"] == 10
    assert tr["dropped"] == 6                  # visible, never silent
    assert [e["eid"] for e in tr["events"]] == [6, 7, 8, 9]
    assert [e["args"]["n"] for e in tr["events"]] == [6, 7, 8, 9]


def test_span_nesting_parents_and_modeled_clock():
    prev = telemetry.install(telemetry.Telemetry())
    tele = telemetry.current()
    t = tracing.Tracer()
    try:
        with t.span("round"):
            tele.advance(1e-6)
            with t.span("pass", rid=7, kind="fused_decode"):
                tele.advance(2e-6)
                t.event("emit.first_token", rid=7)
    finally:
        telemetry.uninstall(prev)
    evs = t.snapshot()["tracks"][tracing.SERVE_TRACK]["events"]
    # spans record at CLOSE (innermost first); eids are reserved at open
    assert [e["name"] for e in evs] == ["emit.first_token", "pass", "round"]
    emit = evs[0]
    pas = evs[1]
    rnd = evs[2]
    assert rnd["eid"] == 0 and "parent" not in rnd
    assert pas["parent"] == rnd["eid"]
    assert emit["parent"] == pas["eid"]
    # integer-ns timestamps on the modeled clock
    assert (rnd["ts"], rnd["dur"]) == (0, 3000)
    assert (pas["ts"], pas["dur"]) == (1000, 2000)
    assert emit["ts"] == 3000 and emit["ph"] == "I"
    assert pas["rid"] == 7 and pas["args"] == {"kind": "fused_decode"}


def test_nonowner_thread_routes_to_streamer_cursor():
    t = tracing.Tracer()

    def worker():
        t.event("xfer", dur_ns=100, bytes=5)
        t.event("stream.task", dur_ns=50)

    th = threading.Thread(target=worker)
    th.start()
    th.join()
    evs = t.snapshot()["tracks"][tracing.STREAM_TRACK]["events"]
    # never reads the modeled clock: FIFO cursor chaining instead
    assert [e["ts"] for e in evs] == [0, 100]
    assert evs[0]["dur"] == 100 and evs[0]["ph"] == "X"
    assert all("parent" not in e for e in evs)


def test_span_raises_off_owner_thread():
    t = tracing.Tracer()
    errs = []

    def worker():
        try:
            with t.span("x"):
                pass
        except RuntimeError as e:
            errs.append(e)

    th = threading.Thread(target=worker)
    th.start()
    th.join()
    assert len(errs) == 1 and "owner" in str(errs[0])


def test_module_helpers_noop_when_uninstalled():
    assert tracing.current() is None
    assert not tracing.active()
    tracing.event("x", rid=1)                  # silent no-ops
    with tracing.span("y"):
        pass
    assert tracing.current() is None


def test_install_uninstall_restores_previous():
    a = tracing.Tracer()
    prev = tracing.install(a)
    assert prev is None
    b = tracing.Tracer()
    prev = tracing.install(b)
    assert prev is a
    tracing.uninstall(prev)
    assert tracing.current() is a
    tracing.uninstall()
    assert tracing.current() is None


# ---------------------------------------------------------------------------
# engine level: a traced faulted run through the real serving stack
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    import jax
    from repro.configs.registry import PAPER_ARCHS
    from repro.models import build_model

    cfg = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                              dtype="float32", num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)
    return cfg, model, params, prompts


def _traced_run(served):
    """Tiered + replicated continuous run with one worker death at step 5
    and one injected streamer delay — exercises every trace source."""
    from repro.serving import Request, ServingEngine
    cfg, model, params, prompts = served
    prev_tele = telemetry.install(telemetry.Telemetry())
    tracer = tracing.Tracer()
    prev_tr = tracing.install(tracer)
    try:
        eng = ServingEngine(cfg, model, params, 2, paged=True, tiered=True,
                            kv_pool_blocks=128, host_cache_blocks=16,
                            ssd_cache_blocks=32, replication=True)
        reqs = [Request(rid=i, prompt=p.copy(), max_new=4)
                for i, p in enumerate(prompts)]
        plan = faults.FaultPlan([faults.FaultSpec(
            "stream.task", nth=2, kind="delay", delay_s=1e-3)])
        rep = eng.run_continuous(reqs, max_active=2, fail_at={5: 1},
                                 fault_plan=plan)
        snapshot = telemetry.current().snapshot()
    finally:
        tracing.uninstall(prev_tr)
        telemetry.uninstall(prev_tele)
    assert rep.recoveries == 1
    return rep, tracer, snapshot


@pytest.fixture(scope="module")
def traced(served):
    rep, tracer, tele_snap = _traced_run(served)
    return rep, tracer.snapshot(), tracer.to_json(), tele_snap


def test_traced_run_covers_all_sources(traced):
    _, trace, _, _ = traced
    serve_names = {e["name"] for e in trace["tracks"]["serve"]["events"]}
    assert {"round", "pass", "sched.admit", "sched.plan", "sched.retire",
            "emit.first_token", "cluster.kill", "recovery"} <= serve_names
    stream_names = {e["name"]
                    for e in trace["tracks"]["streamer"]["events"]}
    assert {"xfer", "stream.task", "fault.delay"} <= stream_names
    # per-worker stage tracks exist alongside serve/streamer
    assert any(t.startswith("w") for t in trace["tracks"])
    assert all(t["dropped"] == 0 for t in trace["tracks"].values())


def test_determinism_byte_identical_dumps(served, traced):
    """Two identical runs must produce byte-identical trace dumps — the
    recorder's headline guarantee (same as telemetry's)."""
    _, _, dump_a, _ = traced
    _, tracer_b, _ = _traced_run(served)
    assert dump_a == tracer_b.to_json()


def test_trace_report_attributes_wall_time(traced, tmp_path):
    _, trace, dump, _ = traced
    report = trace_report.analyze(trace)
    assert len(report["requests"]) == 3
    for r in report["requests"].values():
        assert r["coverage"] >= 0.95            # acceptance criterion (c)
    assert report["bubbles"]["wall_total_ns"] > 0
    assert not report["dropped"]
    # the CLI gate CI runs over the failures-benchmark artifact
    p = tmp_path / "trace.json"
    p.write_text(dump)
    assert trace_report.main([str(p), "--assert"]) == 0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_perfetto_export_tracks_and_instants(traced):
    _, trace, _, _ = traced
    doc = exporters.trace_to_perfetto(trace)
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert len(meta) == len(trace["tracks"])    # one named thread per track
    names = {m["tid"]: m["args"]["name"] for m in meta}
    assert names[1] == "serve"                  # serve first, streamer last
    assert names[max(names)] == "streamer"
    assert any(e["ph"] == "X" and e.get("dur", 0) > 0 for e in evs)
    insts = [e for e in evs if e["ph"] == "i"]
    assert any(e["name"].startswith("fault.") for e in insts)
    assert all(e["s"] == "t" for e in insts)
    json.dumps(doc)                             # serialisable as-is


def test_prometheus_export_text_format(traced):
    _, _, _, tele_snap = traced
    text = exporters.telemetry_to_prometheus(tele_snap)
    assert text.endswith("\n")
    lines = text.splitlines()
    assert any(line.startswith("# TYPE engine_ttft_s histogram")
               for line in lines)
    assert any('engine_ttft_s_bucket{le="+Inf"}' in line for line in lines)
    assert any(line.startswith("faults_fired_total{") for line in lines)
    assert any(line.startswith("modeled_clock_seconds ") for line in lines)
    # cumulative buckets: counts never decrease within a histogram family
    buckets = [int(line.rsplit(" ", 1)[1]) for line in lines
               if line.startswith("engine_ttft_s_bucket{")]
    assert buckets == sorted(buckets)


def test_otlp_export_parents_resolve(traced):
    _, trace, _, _ = traced
    doc = exporters.trace_to_otlp(trace)
    spans = doc["resourceSpans"][0]["scopeSpans"][0]["spans"]
    assert len(spans) == sum(len(t["events"])
                             for t in trace["tracks"].values())
    serve_ids = {s["spanId"] for s in spans
                 if any(a["key"] == "track"
                        and a["value"]["stringValue"] == "serve"
                        for a in s["attributes"])}
    parents = {s["parentSpanId"] for s in spans if "parentSpanId" in s}
    assert parents and parents <= serve_ids     # causal links resolve
    assert all(len(s["traceId"]) == 32 and len(s["spanId"]) == 16
               for s in spans)
    json.dumps(doc)


# ---------------------------------------------------------------------------
# golden schemas: exported key sets and version strings are API
# ---------------------------------------------------------------------------

def test_golden_schema_key_sets(traced):
    """Renderers (render_tables / render_compare / exporters / CI trend
    gate) all consume these exact key sets; a rename is a breaking change
    that must show up here, not in a downstream tool."""
    _, trace, _, tele_snap = traced
    assert tele_snap["schema"] == "repro.telemetry/v1"
    assert sorted(tele_snap) == ["clock_s", "counters", "gauges",
                                 "histograms", "schema", "spans"]
    for h in tele_snap["histograms"].values():
        assert sorted(h) == ["buckets_s", "count", "counts", "max_s",
                             "min_s", "p50_s", "p90_s", "p99_s", "sum_s"]
    for s in tele_snap["spans"].values():
        assert sorted(s) == ["count", "max_s", "total_s"]

    assert trace["schema"] == "repro.trace/v1"
    assert sorted(trace) == ["capacity", "schema", "tracks"]
    required = {"eid", "name", "ph", "ts"}
    allowed = required | {"dur", "parent", "rid", "seq", "args"}
    for tr in trace["tracks"].values():
        assert sorted(tr) == ["dropped", "emitted", "events"]
        for ev in tr["events"]:
            keys = set(ev)
            assert required <= keys <= allowed, f"unexpected keys in {ev}"
            assert ev["ph"] in ("X", "I")
