"""Tiered KV-cache hierarchy (HBM→host→SSD): exact round-trips through every
tier pair, capacity enforcement + LRU spill, SSD atomicity, cross-request
prefix reuse, preempt-to-host→resume token identity, recovery with tiers,
and the planner's tier terms."""
import dataclasses
import os

import numpy as np
import pytest

from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.dejavulib import HostMemoryStore, SSDStore, StreamEngine
from repro.core.planner import MachineSpec, TierSpec, min_token_depth, plan
from repro.kvcache.paged import BlockPool, PagedKVCache
from repro.kvcache.tiers import TIER_HOST, TIER_SSD, KVTierManager, TierConfig

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # optional dev dep (requirements-dev.txt)
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# unit level: the tier manager round-trips bytes exactly
# ---------------------------------------------------------------------------

def _mgr(tmp_path, host_cap=None, ssd_cap=None, block_size=4, name="t"):
    pool = BlockPool(8, block_size)
    pages = PagedKVCache(pool, layers=2, num_kv_heads=2, head_dim=4,
                         dtype="float32")
    streamer = StreamEngine(f"test-{name}")
    cfg = TierConfig(host_capacity_blocks=host_cap, ssd_capacity_blocks=ssd_cap,
                     ssd_root=str(tmp_path / name))
    return KVTierManager(pool, pages, streamer, cfg=cfg, name=name)


def _block(rng, layers=2, w=4, h=2, d=4):
    return {"k": rng.standard_normal((layers, w, h, d)).astype(np.float32),
            "v": rng.standard_normal((layers, w, h, d)).astype(np.float32)}


def _assert_block_equal(a, b):
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(a[leaf], b[leaf])


def test_prefix_roundtrip_hbm_host(tmp_path):
    """evict→promote through tier 1 is byte-exact."""
    mgr = _mgr(tmp_path)
    rng = np.random.default_rng(0)
    blocks = {h: _block(rng) for h in range(5)}
    for h, arrs in blocks.items():
        assert mgr.cache_prefix_block(h, arrs)
    got = mgr.fetch_prefix_chain(list(blocks))
    for h, arrs in blocks.items():
        _assert_block_equal(got[h], arrs)
    assert mgr.stats()["host_hits"] == 5


def test_prefix_roundtrip_through_ssd(tmp_path):
    """host pressure spills LRU blocks to SSD; promotion brings them back
    byte-exact and re-earns them a host slot."""
    mgr = _mgr(tmp_path, host_cap=2)
    rng = np.random.default_rng(1)
    blocks = {h: _block(rng) for h in range(6)}
    for h, arrs in blocks.items():
        mgr.cache_prefix_block(h, arrs)
    st_ = mgr.stats()
    assert st_["host_blocks"] <= 2 and st_["spills"] >= 4
    got = mgr.fetch_prefix_chain(list(blocks))
    for h, arrs in blocks.items():
        _assert_block_equal(got[h], arrs)
    assert mgr.stats()["ssd_hits"] >= 4
    # promotion-on-hit moved the last-read blocks up: a second fetch of the
    # chain tail is served by the host tier
    tail = list(blocks)[-2:]
    before = mgr.stats().get("host_hits", 0)
    mgr.fetch_prefix_chain(tail)
    assert mgr.stats().get("host_hits", 0) > before


def test_prefix_direct_to_ssd_when_host_disabled(tmp_path):
    mgr = _mgr(tmp_path, host_cap=0)
    rng = np.random.default_rng(2)
    arrs = _block(rng)
    mgr.cache_prefix_block(7, arrs)
    got = mgr.fetch_prefix_chain([7])
    _assert_block_equal(got[7], arrs)
    assert mgr.stats()["ssd_hits"] == 1


def test_swap_roundtrip_every_tier_pair(tmp_path):
    """A preempted sequence's blocks round-trip exactly whether they landed
    in host RAM, spilled to SSD, or were re-offloaded dirty."""
    mgr = _mgr(tmp_path, host_cap=1)
    rng = np.random.default_rng(3)
    blocks = {j: _block(rng) for j in range(4)}   # host cap 1 → 3 spill
    mgr.swap_out_blocks(5, blocks)
    got = mgr.swap_in_blocks(5)
    assert set(got) == set(blocks)
    for j in blocks:
        _assert_block_equal(got[j], blocks[j])
    # dirty re-offload of one block replaces every stale copy
    blocks2 = {2: _block(rng)}
    mgr.swap_out_blocks(5, blocks2)
    got2 = mgr.swap_in_blocks(5)
    _assert_block_equal(got2[2], blocks2[2])
    _assert_block_equal(got2[1], blocks[1])
    mgr.drop_seq(5)
    assert mgr.swap_in_blocks(5) == {}


def test_reattach_rebuilds_index_from_ssd(tmp_path):
    """Worker death: host tier dies, SSD survives; a fresh manager on the
    same root recovers prefix blocks AND fully-spilled swap chains."""
    mgr = _mgr(tmp_path, host_cap=0, name="re")
    rng = np.random.default_rng(4)
    pfx = _block(rng)
    swp = {0: _block(rng), 1: _block(rng)}
    mgr.cache_prefix_block(11, pfx)
    mgr.swap_out_blocks(3, swp)
    mgr.streamer.drain()
    mgr.on_host_failure()

    fresh = _mgr(tmp_path, host_cap=0, name="re")   # same ssd_root
    assert fresh.reattach() == 3
    assert fresh.has_prefix(11)
    _assert_block_equal(fresh.fetch_prefix_chain([11])[11], pfx)
    got = fresh.restore_swap_from_ssd(3, keep=2)
    assert got is not None
    for j in swp:
        _assert_block_equal(got[j], swp[j])
    assert fresh.restore_swap_from_ssd(3, keep=3) is None   # chain incomplete


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 3), st.integers(1, 6), st.integers(0, 2**31 - 1))
    def test_property_roundtrip_any_capacity(host_cap, n_blocks, seed):
        """Any host capacity × chain length: every block survives the
        hierarchy byte-exact (the spill path may differ per draw)."""
        import tempfile
        rng = np.random.default_rng(seed)
        with tempfile.TemporaryDirectory() as td:
            pool = BlockPool(8, 4)
            pages = PagedKVCache(pool, layers=1, num_kv_heads=1, head_dim=2,
                                 dtype="float32")
            mgr = KVTierManager(pool, pages, StreamEngine("hyp"),
                                cfg=TierConfig(host_capacity_blocks=host_cap,
                                               ssd_root=td))
            blocks = {h: _block(rng, layers=1, w=4, h=1, d=2)
                      for h in range(n_blocks)}
            for h, arrs in blocks.items():
                mgr.cache_prefix_block(h, arrs)
            got = mgr.fetch_prefix_chain(list(blocks))
            for h, arrs in blocks.items():
                for leaf in ("k", "v"):
                    np.testing.assert_array_equal(got[h][leaf], arrs[leaf])
            mgr.streamer.close()


# ---------------------------------------------------------------------------
# satellite fixes: store capacity enforcement + SSD atomicity
# ---------------------------------------------------------------------------

def test_host_store_capacity_raises():
    store = HostMemoryStore("cap", capacity_bytes=100)
    store.put("a", np.zeros(20, np.int8))
    with pytest.raises(MemoryError):
        store.put("b", np.zeros(101, np.int8))
    assert "b" not in store and store.used_bytes() == 20


def test_host_store_evict_lru_spills_oldest():
    spilled = []
    store = HostMemoryStore("lru", capacity_bytes=100, on_full="evict_lru",
                            spill_cb=lambda k, a: spilled.append(k))
    store.put("a", np.zeros(40, np.int8))
    store.put("b", np.zeros(40, np.int8))
    _ = store.get("a")                       # touch: b becomes LRU
    store.put("c", np.zeros(40, np.int8))    # must evict b, not a
    assert spilled == ["b"]
    assert "a" in store and "c" in store and "b" not in store
    with pytest.raises(MemoryError):         # single over-capacity array
        store.put("huge", np.zeros(101, np.int8))


def test_ssd_store_atomic_put(tmp_path, monkeypatch):
    """A crash mid-flush can never publish a torn block: the interrupted put
    leaves no .npy and no temp litter, and an existing value is kept."""
    store = SSDStore(str(tmp_path))
    good = np.arange(16, dtype=np.float32)
    store.put("blk", good)

    real_save = np.save
    def exploding_save(f, arr):
        f.write(b"partial garbage")
        raise IOError("simulated crash mid-write")
    monkeypatch.setattr(np, "save", exploding_save)
    with pytest.raises(IOError):
        store.put("blk", np.zeros(16, np.float32))
    monkeypatch.setattr(np, "save", real_save)

    np.testing.assert_array_equal(store.get("blk"), good)  # old value intact
    assert not [f for f in os.listdir(store.root) if ".tmp." in f]
    # an orphaned tmp file from a crashed OTHER writer is invisible to keys()
    open(os.path.join(store.root, "zzz.npy.tmp.123.456"), "wb").close()
    assert store.keys() == ["blk"]


# ---------------------------------------------------------------------------
# e2e: serving engine over the tier hierarchy
# ---------------------------------------------------------------------------

CFG = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                          dtype="float32", num_layers=2)
N_SHARED, N_TAIL = 24, 8


@pytest.fixture(scope="module")
def served():
    import jax
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    sys_prompt = rng.integers(0, CFG.vocab_size, (N_SHARED,)).astype(np.int32)
    prompts = [np.concatenate([sys_prompt,
                               rng.integers(0, CFG.vocab_size,
                                            (N_TAIL,)).astype(np.int32)])
               for _ in range(4)]

    def mkreqs(max_new=5):
        return [Request(rid=i, prompt=p.copy(), max_new=max_new)
                for i, p in enumerate(prompts)]

    def engine(**kw):
        return ServingEngine(CFG, model, params, 2, paged=True, **kw)

    baseline = engine(kv_pool_blocks=128).run_continuous(mkreqs(), max_active=1)
    return engine, mkreqs, baseline


def test_cross_request_prefix_reuse_from_tiers(served):
    """max_active=1 retires each request before the next admits, so every
    prefix hit is served from host/SSD — and saves ≥30% of prefill tokens
    with bit-identical greedy outputs."""
    engine, mkreqs, baseline = served
    eng = engine(tiered=True, kv_pool_blocks=128, host_cache_blocks=16,
                 ssd_cache_blocks=64)
    rep = eng.run_continuous(mkreqs(), max_active=1)
    assert rep.tokens == baseline.tokens
    assert rep.prefill_tokens_saved / rep.prefill_tokens_total >= 0.30
    assert rep.tier_stats["host_hits"] + rep.tier_stats.get("ssd_hits", 0) > 0
    assert rep.tier_stats["demotions"] > 0


@pytest.mark.slow
def test_prefix_reuse_via_ssd_spill(served):
    """With a 1-block host tier the same reuse must promote through SSD."""
    engine, mkreqs, baseline = served
    eng = engine(tiered=True, kv_pool_blocks=128, host_cache_blocks=1,
                 ssd_cache_blocks=64)
    rep = eng.run_continuous(mkreqs(), max_active=1)
    assert rep.tokens == baseline.tokens
    assert rep.tier_stats.get("ssd_hits", 0) > 0
    assert rep.tier_stats.get("spills", 0) > 0


@pytest.mark.slow
def test_tight_tier_caps_never_crash_and_keep_reuse(served):
    """Regression: with BOTH tiers capacity-starved, mid-chain promotion
    used to evict-and-drop the very entry being fetched (KeyError), and
    head-first SSD eviction stranded whole chains (0% reuse).  Pinning +
    MRU prefix eviction keep the loop alive and the chain head useful."""
    engine, mkreqs, baseline = served
    eng = engine(tiered=True, kv_pool_blocks=128, host_cache_blocks=2,
                 ssd_cache_blocks=2)
    rep = eng.run_continuous(mkreqs(), max_active=1)
    assert rep.tokens == baseline.tokens
    assert rep.prefill_tokens_saved > 0


def test_boundary_prompt_admission_not_overcounted(served):
    """Regression: a prompt whose length is a block multiple had its LAST
    full block discounted by admission but NOT shared by adoption (the chain
    is capped one block short), over-admitting into forced preemptions."""
    import jax
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, CFG.vocab_size, (16,)).astype(np.int32)  # 2 blocks

    def reqs():
        return [Request(rid=i, prompt=prompt.copy(), max_new=4)
                for i in range(2)]

    flat = ServingEngine(CFG, model, params, 2, paged=True, kv_pool_blocks=64)
    rb = flat.run_continuous(reqs(), max_active=2)
    eng = ServingEngine(CFG, model, params, 2, paged=True, tiered=True,
                        kv_pool_blocks=4)
    rep = eng.run_continuous(reqs(), max_active=2)
    assert rep.tokens == rb.tokens
    assert rep.preemptions == 0     # admission must not overcommit the pool


def test_write_behind_errors_surface_on_next_read(tmp_path):
    """Regression: a failed demotion (e.g. disk full) used to be swallowed
    by the streamer; the next read must raise it instead of serving a
    stranded entry."""
    mgr = _mgr(tmp_path, host_cap=0, name="err")
    rng = np.random.default_rng(9)
    mgr.cache_prefix_block(1, _block(rng))

    def exploding_put(key, arr):
        raise IOError("disk full")
    mgr.ssd.put = exploding_put
    with pytest.raises(RuntimeError, match="write-behind"):
        mgr.fetch_prefix_chain([1])


@pytest.mark.slow
def test_preempt_to_host_resume_token_identical(served):
    """e2e satellite: a preempt-to-tier → resume trace is token-identical to
    the never-preempted run, including when the swap spilled to SSD."""
    engine, mkreqs, _ = served
    big = engine(kv_pool_blocks=128).run_continuous(mkreqs(max_new=10),
                                                    max_active=2)
    tiny = engine(tiered=True, kv_pool_blocks=7, host_cache_blocks=2,
                  ssd_cache_blocks=64)
    rep = tiny.run_continuous(mkreqs(max_new=10), max_active=2)
    assert rep.preemptions >= 1
    assert rep.tokens == big.tokens
    assert rep.tier_stats.get("spills", 0) > 0      # swap crossed into SSD


@pytest.mark.slow
def test_failure_recovery_with_tiers(served):
    """Killing a worker mid-trace: the fresh worker reattaches the dead
    machine's persistent SSD tier and regenerates identical tokens."""
    engine, mkreqs, baseline = served
    eng = engine(tiered=True, replication=True, kv_pool_blocks=128,
                 host_cache_blocks=8, ssd_cache_blocks=64)
    rep = eng.run_continuous(mkreqs(), max_active=2, fail_at={9: 1})
    assert rep.failures == 1 and rep.recoveries == 1
    assert rep.tokens == baseline.tokens


@pytest.mark.slow
def test_failure_while_preempted_with_tiers(served):
    """A worker dies while sequences are swapped through the hierarchy: the
    rolled-back sequences regenerate bit-identically (from the SSD tier
    where it holds the full chain, else the replica ring)."""
    engine, mkreqs, _ = served
    big = engine(kv_pool_blocks=128).run_continuous(mkreqs(max_new=10),
                                                    max_active=2)
    eng = engine(tiered=True, replication=True, kv_pool_blocks=7,
                 host_cache_blocks=0)
    rep = eng.run_continuous(mkreqs(max_new=10), max_active=2, fail_at={12: 1})
    assert rep.preemptions >= 1 and rep.recoveries == 1
    assert rep.tokens == big.tokens


@pytest.mark.slow
def test_disaggregated_prefix_reuse(served):
    """Prompt-side workers keep their own tiers in disaggregated mode, so
    reuse works there too (prefill happens on the prompt pipeline)."""
    import jax
    from repro.models import build_model
    from repro.serving import ServingEngine

    engine, mkreqs, baseline = served
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(CFG, model, params, 4, mode="disaggregated",
                        dp_split=(2, 2), paged=True, tiered=True,
                        kv_pool_blocks=128, host_cache_blocks=16)
    rep = eng.run_continuous(mkreqs(), max_active=1)
    assert rep.tokens == baseline.tokens
    assert rep.prefill_tokens_saved > 0


# ---------------------------------------------------------------------------
# planner: tier capacities + promotion latency terms
# ---------------------------------------------------------------------------

def test_tiered_token_depth_never_worse():
    cfg = PAPER_ARCHS["opt-66b"]
    wl = cm.WorkloadSpec(prompt_len=200, new_tokens=2000, microbatch=32)
    mach = MachineSpec()
    tiers = TierSpec(host_blocks=4096, ssd_blocks=16384)
    dt_flat = min_token_depth(cfg, wl, mach, paged=True)
    dt_tier = min_token_depth(cfg, wl, mach, paged=True, tiers=tiers)
    assert 0 < dt_tier <= dt_flat


def test_prefix_hit_rate_never_slows_prompt_bound_plan():
    cfg = PAPER_ARCHS["opt-66b"]
    wl = cm.WorkloadSpec(prompt_len=3000, new_tokens=32, microbatch=8)
    base = plan(cfg, wl, 8, paged=True)
    hit = plan(cfg, wl, 8, paged=True, prefix_hit_rate=0.8)
    assert base.feasible and hit.feasible
    assert hit.inv_tp_disagg <= base.inv_tp_disagg


def test_promotion_time_orders_by_tier():
    cfg = PAPER_ARCHS["opt-66b"]
    assert 0 < cm.promotion_time(cfg, 4, 1) < cm.promotion_time(cfg, 4, 2)
    assert cm.write_behind_time(cfg, 4, 1) < cm.write_behind_time(cfg, 4, 2)
