"""Continuous batching over the paged KV pool: token-identity with the
static round-robin path on a mixed-length trace, block-granular streaming
(swap / disaggregation / replication), preemption, and failure recovery."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import PAPER_ARCHS
from repro.models import build_model
from repro.serving import Request, ServingEngine

CFG = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                          dtype="float32", num_layers=8)
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
RNG = np.random.default_rng(0)

# mixed-length trace: two prompt-length buckets, per-request token budgets
PLENS = [8, 12, 8, 12, 8, 8]
MAXNEW = [6, 3, 7, 4, 3, 6]
PROMPTS = [RNG.integers(0, CFG.vocab_size, (p,)).astype(np.int32)
           for p in PLENS]


def mkreqs(n=len(PLENS)):
    return [Request(rid=i, prompt=PROMPTS[i].copy(), max_new=MAXNEW[i])
            for i in range(n)]


def _tokens_match_static(cont_tokens, static_tokens):
    """Static holds every request to its GROUP's max_new (overgenerating for
    short requests); continuous stops each at its own budget — so compare
    the per-request prefix, which must be bit-identical (greedy)."""
    for rid, toks in cont_tokens.items():
        assert len(toks) == MAXNEW[rid]
        assert static_tokens[rid][:MAXNEW[rid]] == toks, rid
    return True


# 2-stage pipelines keep the fast suite fast; worker count never changes the
# greedy tokens (asserted across depths by the slow tests + test_system)
@pytest.fixture(scope="module")
def static_report():
    eng = ServingEngine(CFG, MODEL, PARAMS, 2, mode="colocated", microbatch=2)
    return eng.run(mkreqs())


@pytest.fixture(scope="module")
def continuous_report():
    eng = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, kv_pool_blocks=64)
    return eng.run_continuous(mkreqs(), max_active=4)


def test_mixed_length_trace_token_identical(static_report, continuous_report):
    assert _tokens_match_static(continuous_report.tokens, static_report.tokens)


def test_continuous_uses_less_peak_kv(static_report, continuous_report):
    assert 0 < continuous_report.peak_kv_bytes < static_report.peak_kv_bytes


def test_continuous_admits_into_freed_slots(continuous_report):
    # with 6 requests and max_active=4, the earliest retirement happens after
    # round 2 (min max_new beats the prefill) — without backfill the trace
    # could hold 4 for at most 2 rounds; admission into freed slots keeps the
    # batch full for longer
    trace = continuous_report.batch_trace
    assert max(trace) == 4
    assert trace.count(4) >= 4, f"batch not backfilled: {trace}"


@pytest.mark.slow
def test_eos_retires_early():
    reqs = mkreqs()
    base = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, kv_pool_blocks=64)
    toks = base.run_continuous(mkreqs(), max_active=3).tokens
    eos = toks[0][2]                      # force an early stop for rid 0
    stop = toks[0].index(eos) + 1         # first occurrence may be earlier
    assert stop < MAXNEW[0]
    reqs[0].eos_id = int(eos)
    eng = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, kv_pool_blocks=64)
    rep = eng.run_continuous(reqs, max_active=3)
    assert len(rep.tokens[0]) == stop and rep.tokens[0] == toks[0][:stop]
    for rid in range(1, len(PLENS)):      # peers unaffected
        assert rep.tokens[rid] == toks[rid]


def test_failure_recovery_regenerates_identical_tokens(static_report):
    eng = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, replication=True,
                        kv_pool_blocks=64)
    rep = eng.run_continuous(mkreqs(), max_active=4, fail_at={9: 1})
    assert rep.failures == 1 and rep.recoveries == 1
    assert _tokens_match_static(rep.tokens, static_report.tokens)
    kinds = [e["kind"] for e in eng.cluster.controller.events]
    assert "failure" in kinds and "recovery" in kinds


@pytest.mark.slow
@pytest.mark.parametrize("fail_step,wid", [(9, 2), (5, 0), (14, 3)])
def test_failure_recovery_more_points(static_report, fail_step, wid):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, paged=True, replication=True,
                        kv_pool_blocks=64)
    rep = eng.run_continuous(mkreqs(), max_active=4,
                             fail_at={fail_step: wid})
    assert rep.recoveries == 1
    assert _tokens_match_static(rep.tokens, static_report.tokens)


@pytest.mark.slow
def test_swapping_streams_blocks(static_report):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, paged=True, swapping=True,
                        kv_pool_blocks=64)
    rep = eng.run_continuous(mkreqs(), max_active=4)
    assert _tokens_match_static(rep.tokens, static_report.tokens)
    assert eng.transfer_summary()["hostlink"] > 0


@pytest.mark.slow
def test_disaggregated_streams_prompt_blocks(static_report):
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, mode="disaggregated",
                        dp_split=(2, 2), paged=True, kv_pool_blocks=64)
    rep = eng.run_continuous(mkreqs(), max_active=4)
    assert _tokens_match_static(rep.tokens, static_report.tokens)
    assert eng.transfer_summary()["net"] > 0      # blocks crossed the wire


@pytest.mark.slow
def test_preemption_under_tiny_pool():
    prompts = [RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
               for _ in range(2)]

    def reqs():
        return [Request(rid=i, prompt=prompts[i].copy(), max_new=10)
                for i in range(2)]

    base = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, kv_pool_blocks=64)
    rb = base.run_continuous(reqs(), max_active=2)
    eng = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, kv_pool_blocks=4)
    rp = eng.run_continuous(reqs(), max_active=2)
    assert rp.preemptions >= 1
    assert rp.tokens == rb.tokens


def test_prefix_sharing_saves_blocks():
    shared = RNG.integers(0, CFG.vocab_size, (16,)).astype(np.int32)
    reqs = [Request(rid=i, prompt=shared.copy(), max_new=4) for i in range(3)]
    eng = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, kv_pool_blocks=64)
    rep = eng.run_continuous(reqs, max_active=3)
    assert len({tuple(t) for t in rep.tokens.values()}) == 1
    w = eng.cluster.token_group[0]
    # 3 seqs x (2 full prompt blocks shared + own growth blocks): well under
    # the 9 blocks an unshared pool would peak at
    assert w.pool.peak_used_blocks < 9


def test_max_new_one_emits_exactly_one_token():
    # a request admitted and retired in the same round must not be decoded
    # past its budget by the round's step loop
    reqs = [Request(rid=i, prompt=PROMPTS[i].copy(), max_new=[1, 4, 2][i])
            for i in range(3)]
    eng = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, kv_pool_blocks=64)
    rep = eng.run_continuous(reqs, max_active=3)
    assert [len(rep.tokens[i]) for i in range(3)] == [1, 4, 2]


@pytest.mark.slow
def test_failure_while_preempted_recovers():
    """A worker dies while a sequence is swapped out by preemption: its swap
    copy dies with the worker, so recovery must rebuild it from the ring
    replica and the rolled-back sequence must regenerate identically."""
    prompts = [RNG.integers(0, CFG.vocab_size, (8,)).astype(np.int32)
               for _ in range(2)]

    def reqs():
        return [Request(rid=i, prompt=prompts[i].copy(), max_new=10)
                for i in range(2)]

    base = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True,
                         kv_pool_blocks=64).run_continuous(reqs(), max_active=2)
    eng = ServingEngine(CFG, MODEL, PARAMS, 2, paged=True, replication=True,
                        kv_pool_blocks=4)
    rep = eng.run_continuous(reqs(), max_active=2, fail_at={12: 1})
    assert rep.preemptions >= 1 and rep.recoveries == 1
    assert rep.tokens == base.tokens


@pytest.mark.slow
def test_paged_repartition_streams_blocks(static_report):
    """Elastic repartitioning mid-flight moves live blocks only."""
    eng = ServingEngine(CFG, MODEL, PARAMS, 4, paged=True, kv_pool_blocks=64)
    cl = eng.cluster
    reqs = mkreqs(2)
    import jax.numpy as jnp
    from repro.serving.sampling import greedy
    toks = {r.rid: [] for r in reqs}
    for r in reqs:
        logits = cl.prefill_seq(r.rid, r.prompt, r.max_new)
        toks[r.rid].append(int(greedy(logits)[0]))
    for step in range(1, 4):
        if step == 2:
            cl.repartition(3, [r.rid for r in reqs])
        for r in reqs:
            last = np.asarray([toks[r.rid][-1]], np.int32)
            logits = cl.decode_seq(r.rid, jnp.asarray(last), step)
            toks[r.rid].append(int(greedy(logits)[0]))
    assert len(cl.token_group) == 3
    for r in reqs:
        assert toks[r.rid] == static_report.tokens[r.rid][:4]
