"""Planner (paper Eqs. 1–6) unit + hypothesis property tests."""
import math

import pytest

pytest.importorskip("hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.core import costmodel as cm
from repro.core.dejavulib.transport import DEFAULT_HW
from repro.core.planner import (MachineSpec, colocated_inverse_throughput,
                                estimate_m, min_prompt_depth, min_token_depth,
                                plan)

CFG = get_arch("opt-66b")
MACH = MachineSpec()


def test_eq3_formula():
    # I_c = (D−1)(Y−t)/D + Y + N·t
    assert colocated_inverse_throughput(4, 2.0, 0.1, 100) == pytest.approx(
        3 * 1.9 / 4 + 2.0 + 10.0)


def test_plan_opt66b_feasible_and_beneficial():
    wl = cm.WorkloadSpec(prompt_len=1000, new_tokens=220, microbatch=16)
    p = plan(CFG, wl, 8, MACH)
    assert p.feasible
    assert p.d_prompt + p.d_token == 8
    assert p.disagg_beneficial
    assert 1.0 <= p.m_overhead < 2.0


def test_plan_infeasible_when_memory_too_small():
    wl = cm.WorkloadSpec(prompt_len=4000, new_tokens=500, microbatch=64)
    small = MachineSpec(chips=2, mem_bytes=2 * 16e9)
    p = plan(CFG, wl, 4, small)
    assert not p.feasible


@settings(max_examples=40, deadline=None)
@given(d=st.integers(4, 24),
       prompt=st.sampled_from([500, 1000, 2000]),
       new_tokens=st.sampled_from([50, 150, 400]),
       mb=st.sampled_from([4, 8, 16]))
def test_plan_properties(d, prompt, new_tokens, mb):
    wl = cm.WorkloadSpec(prompt, new_tokens, mb)
    p = plan(CFG, wl, d, MACH)
    if not p.feasible:
        return
    # split is a partition respecting the memory floors (Eqs. 1–2)
    assert p.d_prompt + p.d_token == d
    assert p.d_prompt >= 1 and p.d_token >= 1
    assert p.d_token >= min_token_depth(CFG, wl, MACH)
    # I_dis is the max of a balanced pair and never negative
    assert p.inv_tp_disagg > 0
    # the integer split is optimal among all feasible splits (brute force)
    best = None
    y = cm.stage_prompt_time(CFG, wl, CFG.num_layers, d * MACH.chips)
    t = cm.stage_token_time(CFG, wl, CFG.num_layers, d * MACH.chips,
                            prompt + new_tokens)
    for dt in range(max(min_token_depth(CFG, wl, MACH), 1),
                    d - min_prompt_depth(CFG, wl, MACH) + 1):
        dp = d - dt
        m = estimate_m(CFG, wl, y, dp, MACH, DEFAULT_HW)
        cand = max(m * y * d / dp, new_tokens * t * d / dt)
        if best is None or cand < best:
            best = cand
    assert p.inv_tp_disagg == pytest.approx(best)


def test_larger_n_shifts_machines_to_token_side():
    """Paper: larger N ⇒ larger D_t (more token machines)."""
    wl_small = cm.WorkloadSpec(1000, 50, 16)
    wl_large = cm.WorkloadSpec(1000, 600, 16)
    p1 = plan(CFG, wl_small, 12, MACH)
    p2 = plan(CFG, wl_large, 12, MACH)
    assert p1.feasible and p2.feasible
    assert p2.d_token >= p1.d_token


def test_larger_prompt_shifts_machines_to_prompt_side():
    """Paper: larger Y/t ⇒ larger D_p."""
    p1 = plan(CFG, cm.WorkloadSpec(250, 200, 8), 12, MACH)
    p2 = plan(CFG, cm.WorkloadSpec(2000, 200, 8), 12, MACH)
    assert p1.feasible and p2.feasible
    assert p2.d_prompt >= p1.d_prompt


def test_replan_after_failure_shrinks():
    from repro.core.planner import replan_after_failure
    wl = cm.WorkloadSpec(1000, 220, 16)
    p = plan(CFG, wl, 12, MACH)
    p2 = replan_after_failure(p, CFG, wl, 11, mach=MACH)
    assert p2.d_prompt + p2.d_token == 11
