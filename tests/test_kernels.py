"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.kv_pack import kv_pack, kv_unpack
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels import ref
from repro.models.ssm import ssd_chunked

pytestmark = pytest.mark.slow  # full sweep; excluded from `pytest -m "not slow"`

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,hq,hkv,d,causal,bq,bk", [
    (2, 64, 64, 4, 2, 16, True, 32, 32),
    (1, 100, 100, 6, 2, 32, True, 32, 32),       # non-multiple seq
    (2, 32, 96, 4, 4, 16, True, 16, 32),         # cross-length causal
    (1, 64, 64, 2, 1, 64, False, 64, 64),        # bidirectional
    (1, 128, 128, 8, 8, 16, True, 128, 128),     # MHA single block
])
def test_flash_attention(b, sq, skv, hq, hkv, d, causal, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    expected = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,d,bk,n_valid", [
    (2, 128, 4, 2, 16, 32, 100),
    (1, 100, 8, 2, 32, 64, 100),                 # padding path
    (3, 64, 4, 4, 16, 64, 1),                    # single valid slot
    (1, 256, 2, 1, 64, 256, 200),
])
def test_decode_attention(b, s, hq, hkv, d, bk, n_valid, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    valid = jnp.arange(s) < n_valid
    out = decode_attention(q, k, v, valid, block_k=bk)
    expected = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,B,S,H,D,t0,w,tb", [
    (3, 2, 64, 4, 16, 16, 24, 8),
    (2, 1, 32, 2, 8, 0, 32, 8),                  # whole cache
    (4, 2, 48, 2, 16, 40, 8, 8),                 # tail window
    (1, 1, 16, 1, 8, 8, 8, 4),
])
def test_kv_pack_unpack_roundtrip(L, B, S, H, D, t0, w, tb, dtype):
    ks = jax.random.split(KEY, 2)
    cache = jax.random.normal(ks[0], (L, B, S, H, D), dtype)
    packed = kv_pack(cache, t0, width=w, token_block=tb)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(ref.kv_pack_ref(cache, t0, w)))
    buf = jax.random.normal(ks[1], (L, B, w, H, D), dtype)
    restored = kv_unpack(cache.copy(), buf, t0, token_block=tb)
    np.testing.assert_array_equal(np.asarray(restored),
                                  np.asarray(ref.kv_unpack_ref(cache, buf, t0)))


@pytest.mark.parametrize("B,S,NH,HD,G,N,CH", [
    (2, 96, 4, 16, 1, 8, 32),
    (1, 64, 8, 8, 2, 16, 16),
    (2, 50, 4, 16, 1, 8, 32),                    # non-multiple of chunk
    (1, 33, 2, 8, 1, 4, 16),
])
def test_ssd_kernel_and_chunked_vs_sequential(B, S, NH, HD, G, N, CH):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, NH, HD), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, NH)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (NH,)) * 0.3)
    bm = 0.5 * jax.random.normal(ks[3], (B, S, G, N), jnp.float32)
    cm = 0.5 * jax.random.normal(ks[0], (B, S, G, N), jnp.float32)
    h0 = 0.1 * jax.random.normal(ks[1], (B, NH, HD, N), jnp.float32)
    y_ref, h_ref = ref.ssd_sequential_ref(x, dt, a_neg, bm, cm, h0=h0)
    y_k, h_k = ssd_scan(x, dt, a_neg, bm, cm, h0=h0, chunk=CH)
    y_j, h_j = ssd_chunked(x, dt, a_neg, bm, cm, chunk=CH, h0=h0)
    np.testing.assert_allclose(y_k, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_k, h_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(y_j, y_ref, rtol=2e-4, atol=2e-4)


def test_ssd_decode_step_matches_sequential():
    from repro.kernels.ref import ssd_sequential_ref
    from repro.models.ssm import ssd_decode_step
    ks = jax.random.split(KEY, 4)
    B, NH, HD, G, N = 2, 4, 8, 1, 8
    x = jax.random.normal(ks[0], (B, 5, NH, HD))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, 5, NH)))
    a_neg = -jnp.exp(0.3 * jax.random.normal(ks[2], (NH,)))
    bm = 0.5 * jax.random.normal(ks[3], (B, 5, G, N))
    cm = 0.5 * jax.random.normal(ks[0], (B, 5, G, N))
    y_ref, h_ref = ssd_sequential_ref(x, dt, a_neg, bm, cm)
    h = jnp.zeros((B, NH, HD, N))
    for t in range(5):
        y, h = ssd_decode_step(x[:, t], dt[:, t], a_neg, bm[:, t], cm[:, t], h)
    np.testing.assert_allclose(y, y_ref[:, -1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h, h_ref, rtol=1e-5, atol=1e-5)
