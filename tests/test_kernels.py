"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import (batched_decode_attention,
                                            decode_attention)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kv_pack import kv_pack, kv_pack_ragged, kv_unpack
from repro.kernels.paged_prefill import paged_prefill_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.models.ssm import ssd_chunked

pytestmark = pytest.mark.slow  # full sweep; excluded from `pytest -m "not slow"`

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,hq,hkv,d,causal,bq,bk", [
    (2, 64, 64, 4, 2, 16, True, 32, 32),
    (1, 100, 100, 6, 2, 32, True, 32, 32),       # non-multiple seq
    (2, 32, 96, 4, 4, 16, True, 16, 32),         # cross-length causal
    (1, 64, 64, 2, 1, 64, False, 64, 64),        # bidirectional
    (1, 128, 128, 8, 8, 16, True, 128, 128),     # MHA single block
])
def test_flash_attention(b, sq, skv, hq, hkv, d, causal, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    expected = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,d,bk,n_valid", [
    (2, 128, 4, 2, 16, 32, 100),
    (1, 100, 8, 2, 32, 64, 100),                 # padding path
    (3, 64, 4, 4, 16, 64, 1),                    # single valid slot
    (1, 256, 2, 1, 64, 256, 200),
])
def test_decode_attention(b, s, hq, hkv, d, bk, n_valid, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    valid = jnp.arange(s) < n_valid
    out = decode_attention(q, k, v, valid, block_k=bk)
    expected = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,d,bk,lengths", [
    (3, 128, 4, 2, 16, 32, (100, 128, 1)),       # ragged incl. extremes
    (2, 100, 8, 2, 32, 64, (37, 99)),            # padding path
    (4, 64, 4, 4, 16, 64, (64, 64, 64, 64)),     # uniform full
    (1, 256, 2, 1, 64, 256, (200,)),
])
def test_batched_decode_attention(b, s, hq, hkv, d, bk, lengths, dtype):
    """Fused-round kernel vs dense oracle: one launch, B sequences each
    masked to its OWN live length (vs `decode_attention`'s shared mask)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    lens = jnp.asarray(lengths, jnp.int32)
    out = batched_decode_attention(q, k, v, lens, block_k=bk)
    expected = ref.batched_decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


def test_batched_decode_matches_per_sequence():
    """Semantic check behind fused rounds: the batched launch reproduces B
    independent single-sequence `decode_attention` calls bit-for-bit."""
    b, s, hq, hkv, d = 3, 64, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    lens = jnp.asarray([17, 64, 5], jnp.int32)
    out = batched_decode_attention(q, k, v, lens, block_k=32)
    for i in range(b):
        one = decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                               jnp.arange(s) < int(lens[i]), block_k=32)
        np.testing.assert_allclose(np.asarray(out[i:i + 1]), np.asarray(one),
                                   rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("use_win,use_bias,num_meta", [
    (True, False, 0),            # sliding window only
    (True, False, 2),            # window + meta-token attention sinks
    (False, True, 0),            # ALiBi slopes only
    (True, True, 2),             # window + meta + ALiBi combined
])
def test_batched_decode_attention_window_bias(use_win, use_bias, num_meta,
                                              dtype):
    """ALiBi / sliding-window variants of the fused-round kernel vs oracle:
    per-sequence window starts and per-head slopes ride scalar prefetch, so
    one launch still serves B ragged sequences with heterogeneous masks."""
    b, s, hq, hkv, d, bk = 3, 96, 4, 2, 16, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    lens = jnp.asarray([90, 96, 7], jnp.int32)
    # window starts as the engine computes them: max(len - w, 0), w = 24;
    # the short sequence starts at 0 (whole context inside the window)
    wins = jnp.maximum(lens - 24, 0) if use_win else None
    slopes = (jnp.asarray([2.0 ** -(i + 1) for i in range(hq)], jnp.float32)
              if use_bias else None)
    out = batched_decode_attention(q, k, v, lens, wins, slopes,
                                   block_k=bk, num_meta=num_meta)
    expected = ref.batched_decode_attention_ref(q, k, v, lens, wins, slopes,
                                                num_meta=num_meta)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


def test_batched_decode_window_bias_matches_per_sequence():
    """The windowed/ALiBi batched launch reproduces B independent dense
    attends with the per-sequence mask/bias semantics the engine's oracle
    path uses (meta sinks visible below `num_meta`, window elsewhere)."""
    b, s, hq, hkv, d, g = 3, 64, 4, 2, 16, 2
    num_meta, w = 2, 12
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    lens = jnp.asarray([40, 64, 9], jnp.int32)
    wins = jnp.maximum(lens - w, 0)
    slopes = jnp.asarray([0.5, 0.25, 0.125, 0.0625], jnp.float32)
    out = batched_decode_attention(q, k, v, lens, wins, slopes,
                                   block_k=32, num_meta=num_meta)
    scale = 1.0 / np.sqrt(d)
    for i in range(b):
        n = int(lens[i])
        pos = np.arange(s)
        visible = (pos < n) & ((pos >= int(wins[i])) | (pos < num_meta))
        qi = np.asarray(q[i], np.float32).reshape(hkv, g, d)
        ki = np.asarray(k[i], np.float32)
        sc = np.einsum("hgd,shd->hgs", qi, ki) * scale
        sc = sc - slopes.reshape(hkv, g)[:, :, None] * np.maximum(
            (n - 1) - pos, 0)[None, None, :]
        sc = np.where(visible[None, None, :], sc, -np.inf)
        p = np.exp(sc - sc.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        o = np.einsum("hgs,shd->hgd", p, np.asarray(v[i], np.float32))
        np.testing.assert_allclose(np.asarray(out[i], np.float32),
                                   o.reshape(hq, d), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,B,S,H,D,starts,w,tb", [
    (3, 3, 64, 4, 16, (0, 16, 56), 8, 8),
    (2, 2, 32, 2, 8, (24, 0), 8, 8),             # tail + head windows
    (1, 4, 48, 2, 16, (8, 8, 40, 16), 8, 4),     # repeated offsets, tb 4
    (2, 1, 16, 1, 8, (8,), 8, 8),                # single row
])
def test_kv_pack_ragged(L, B, S, H, D, starts, w, tb, dtype):
    """Multi-sequence buffered copy vs oracle: one launch packs one window
    per batch row, each at its OWN offset (the fused-round writeback)."""
    cache = jax.random.normal(KEY, (L, B, S, H, D), dtype)
    st = jnp.asarray(starts, jnp.int32)
    packed = kv_pack_ragged(cache, st, width=w, token_block=tb)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(ref.kv_pack_ragged_ref(cache, st, w)))
    # row b of the ragged pack == the scalar kv_pack of that row's window
    for bi in range(B):
        one = kv_pack(cache[:, bi:bi + 1], int(st[bi]), width=w, token_block=tb)
        np.testing.assert_array_equal(np.asarray(packed[:, bi:bi + 1]),
                                      np.asarray(one))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,c,hq,hkv,d,bs,prefixes", [
    (2, 8, 4, 2, 16, 8, (16, 9)),          # aligned + mid-block prefix
    (1, 5, 6, 2, 32, 8, (0,)),             # no prefix (pure self-attention)
    (3, 3, 4, 4, 16, 4, (4, 7, 1)),        # chunk < block, ragged prefixes
    (1, 16, 2, 1, 64, 8, (24,)),           # chunk spans multiple blocks
])
def test_paged_prefill_attention(b, c, hq, hkv, d, bs, prefixes, dtype):
    """Kernel vs dense oracle: a Q chunk attends over its paged prefix plus
    itself, for prefixes/chunks that do and don't align to block boundaries."""
    rng = np.random.default_rng(0)
    max_blocks = max((p + c + bs - 1) // bs for p in prefixes)
    n_pages = b * max_blocks + 1
    ks = jax.random.split(KEY, 3)
    k_pages = jax.random.normal(ks[0], (n_pages, bs, hkv, d), dtype)
    v_pages = jax.random.normal(ks[1], (n_pages, bs, hkv, d), dtype)
    q = jax.random.normal(ks[2], (b, c, hq, d), dtype)
    perm = rng.permutation(n_pages - 1) + 1      # page 0 reserved as padding
    bt = jnp.asarray(perm[:b * max_blocks].reshape(b, max_blocks), jnp.int32)
    q_starts = jnp.asarray(list(prefixes), jnp.int32)
    q_lens = jnp.full((b,), c, jnp.int32)
    out = paged_prefill_attention(q, k_pages, v_pages, bt, q_starts, q_lens)
    expected = ref.paged_prefill_attention_ref(q, k_pages, v_pages, bt,
                                               q_starts, q_lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


def test_paged_prefill_chunks_match_dense_causal():
    """Semantic check: running a sequence through consecutive chunks over
    pages reproduces the rows of one dense causal flash prefill — the
    exactness claim behind chunked prefix adoption."""
    b, s, hq, hkv, d, bs, chunk = 1, 48, 4, 2, 16, 8, 10   # 10 ∤ 48
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    dense = ref.flash_attention_ref(q, k, v, causal=True)
    n_blocks = s // bs
    k_pages = k.reshape(n_blocks, bs, hkv, d)
    v_pages = v.reshape(n_blocks, bs, hkv, d)
    bt = jnp.arange(n_blocks, dtype=jnp.int32)[None]
    for pos in range(0, s, chunk):
        c = min(chunk, s - pos)
        out = paged_prefill_attention(q[:, pos:pos + c], k_pages, v_pages, bt,
                                      jnp.asarray([pos], jnp.int32),
                                      jnp.asarray([c], jnp.int32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(dense[:, pos:pos + c]),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,B,S,H,D,t0,w,tb", [
    (3, 2, 64, 4, 16, 16, 24, 8),
    (2, 1, 32, 2, 8, 0, 32, 8),                  # whole cache
    (4, 2, 48, 2, 16, 40, 8, 8),                 # tail window
    (1, 1, 16, 1, 8, 8, 8, 4),
])
def test_kv_pack_unpack_roundtrip(L, B, S, H, D, t0, w, tb, dtype):
    ks = jax.random.split(KEY, 2)
    cache = jax.random.normal(ks[0], (L, B, S, H, D), dtype)
    packed = kv_pack(cache, t0, width=w, token_block=tb)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(ref.kv_pack_ref(cache, t0, w)))
    buf = jax.random.normal(ks[1], (L, B, w, H, D), dtype)
    restored = kv_unpack(cache.copy(), buf, t0, token_block=tb)
    np.testing.assert_array_equal(np.asarray(restored),
                                  np.asarray(ref.kv_unpack_ref(cache, buf, t0)))


@pytest.mark.parametrize("B,S,NH,HD,G,N,CH", [
    (2, 96, 4, 16, 1, 8, 32),
    (1, 64, 8, 8, 2, 16, 16),
    (2, 50, 4, 16, 1, 8, 32),                    # non-multiple of chunk
    (1, 33, 2, 8, 1, 4, 16),
])
def test_ssd_kernel_and_chunked_vs_sequential(B, S, NH, HD, G, N, CH):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, NH, HD), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, NH)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (NH,)) * 0.3)
    bm = 0.5 * jax.random.normal(ks[3], (B, S, G, N), jnp.float32)
    cm = 0.5 * jax.random.normal(ks[0], (B, S, G, N), jnp.float32)
    h0 = 0.1 * jax.random.normal(ks[1], (B, NH, HD, N), jnp.float32)
    y_ref, h_ref = ref.ssd_sequential_ref(x, dt, a_neg, bm, cm, h0=h0)
    y_k, h_k = ssd_scan(x, dt, a_neg, bm, cm, h0=h0, chunk=CH)
    y_j, h_j = ssd_chunked(x, dt, a_neg, bm, cm, chunk=CH, h0=h0)
    np.testing.assert_allclose(y_k, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_k, h_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(y_j, y_ref, rtol=2e-4, atol=2e-4)


def test_ssd_decode_step_matches_sequential():
    from repro.kernels.ref import ssd_sequential_ref
    from repro.models.ssm import ssd_decode_step
    ks = jax.random.split(KEY, 4)
    B, NH, HD, G, N = 2, 4, 8, 1, 8
    x = jax.random.normal(ks[0], (B, 5, NH, HD))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, 5, NH)))
    a_neg = -jnp.exp(0.3 * jax.random.normal(ks[2], (NH,)))
    bm = 0.5 * jax.random.normal(ks[3], (B, 5, G, N))
    cm = 0.5 * jax.random.normal(ks[0], (B, 5, G, N))
    y_ref, h_ref = ssd_sequential_ref(x, dt, a_neg, bm, cm)
    h = jnp.zeros((B, NH, HD, N))
    for t in range(5):
        y, h = ssd_decode_step(x[:, t], dt[:, t], a_neg, bm[:, t], cm[:, t], h)
    np.testing.assert_allclose(y, y_ref[:, -1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h, h_ref, rtol=1e-5, atol=1e-5)
