"""Benchmark-trend gate (tools/check_bench_trend.py): pass, synthetic
regression, missing-metric, module-absent skip, --update re-baselining,
and the three direction semantics."""
import importlib.util
import json
import os

import pytest

_TOOL = os.path.join(os.path.dirname(__file__), "..", "tools",
                     "check_bench_trend.py")
_spec = importlib.util.spec_from_file_location("check_bench_trend", _TOOL)
cbt = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cbt)


def _write_run(run_dir, module, metrics):
    """One repro.bench/v1 artifact with emit_metric-style rows."""
    os.makedirs(run_dir, exist_ok=True)
    doc = {"schema": "repro.bench/v1",
           "rows": ([{"name": "legacy_row", "us_per_call": 1.0, "derived": ""}]
                    + [{"name": k, "value": v, "note": ""}
                       for k, v in metrics.items()]),
           "telemetry": None}
    with open(os.path.join(run_dir, f"{module}.json"), "w") as f:
        json.dump(doc, f)


def _write_baseline(path, metrics):
    with open(path, "w") as f:
        json.dump({"schema": "repro.bench_baseline/v1", "metrics": metrics}, f)


def test_pass_within_tolerance(tmp_path):
    run = str(tmp_path / "run")
    _write_run(run, "mod", {"m": 1.02})
    base = str(tmp_path / "base.json")
    _write_baseline(base, {"mod/m": {"value": 1.0, "rel_tol": 0.05,
                                     "direction": "two_sided"}})
    assert cbt.main([run, "--baseline", base]) == 0


def test_synthetic_regression_fails(tmp_path):
    """The acceptance row: a regressed metric must exit non-zero."""
    run = str(tmp_path / "run")
    _write_run(run, "mod", {"m": 0.80})          # -20% vs baseline
    base = str(tmp_path / "base.json")
    _write_baseline(base, {"mod/m": {"value": 1.0, "rel_tol": 0.05,
                                     "direction": "two_sided"}})
    assert cbt.main([run, "--baseline", base]) == 1


def test_missing_metric_in_present_module_fails(tmp_path):
    """The module ran but its emit_metric row vanished: failure, not skip."""
    run = str(tmp_path / "run")
    _write_run(run, "mod", {"other": 1.0})
    base = str(tmp_path / "base.json")
    _write_baseline(base, {"mod/m": {"value": 1.0}})
    assert cbt.main([run, "--baseline", base]) == 1


def test_absent_module_skips(tmp_path):
    """Fast-suite runs a subset: metrics of modules that didn't run skip."""
    run = str(tmp_path / "run")
    _write_run(run, "ran", {"m": 1.0})
    base = str(tmp_path / "base.json")
    _write_baseline(base, {"ran/m": {"value": 1.0},
                           "didnotrun/m": {"value": 42.0}})
    assert cbt.main([run, "--baseline", base]) == 0


def test_nan_never_passes(tmp_path):
    run = str(tmp_path / "run")
    _write_run(run, "mod", {"m": float("nan")})
    base = str(tmp_path / "base.json")
    _write_baseline(base, {"mod/m": {"value": 1.0}})
    assert cbt.main([run, "--baseline", base]) == 1


@pytest.mark.parametrize("direction,measured,ok", [
    ("higher_better", 1.20, True),    # improvement never fails
    ("higher_better", 0.94, False),   # below the 5% floor
    ("lower_better", 0.80, True),
    ("lower_better", 1.06, False),
    ("two_sided", 1.04, True),
    ("two_sided", 1.06, False),
])
def test_direction_semantics(direction, measured, ok):
    got, _ = cbt.check_metric(
        "k", measured, {"value": 1.0, "rel_tol": 0.05, "direction": direction})
    assert got is ok


def test_update_rebaselines_and_keeps_tolerances(tmp_path):
    run = str(tmp_path / "run")
    _write_run(run, "mod", {"m": 2.0, "new_metric": 7.0})
    base = str(tmp_path / "base.json")
    _write_baseline(base, {
        "mod/m": {"value": 1.0, "rel_tol": 0.10, "direction": "higher_better"},
        "absent_mod/x": {"value": 3.0}})
    assert cbt.main([run, "--baseline", base, "--update"]) == 0
    doc = cbt.load_baseline(base)
    m = doc["metrics"]
    assert m["mod/m"]["value"] == 2.0
    assert m["mod/m"]["rel_tol"] == 0.10            # tolerance survives
    assert m["mod/m"]["direction"] == "higher_better"
    assert m["absent_mod/x"]["value"] == 3.0        # unmeasured entry kept
    assert m["mod/new_metric"]["value"] == 7.0      # new metric at defaults
    assert cbt.main([run, "--baseline", base]) == 0


def test_bad_schema_and_missing_dir_are_usage_errors(tmp_path):
    base = str(tmp_path / "base.json")
    with open(base, "w") as f:
        json.dump({"schema": "wrong/v0", "metrics": {}}, f)
    run = str(tmp_path / "run")
    _write_run(run, "mod", {"m": 1.0})
    assert cbt.main([run, "--baseline", base]) == 2
    assert cbt.main([str(tmp_path / "nope"), "--baseline", base]) == 2


def test_flush_json_double_flush_raises(tmp_path, monkeypatch):
    """A second flush of the same stem would silently overwrite the CI
    trend artifact with post-flush leftovers; it must error instead."""
    monkeypatch.delenv("BENCH_JSON_DIR", raising=False)
    common_path = os.path.join(os.path.dirname(__file__), "..",
                               "benchmarks", "common.py")
    spec = importlib.util.spec_from_file_location("bench_common", common_path)
    common = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(common)

    monkeypatch.setenv("BENCH_JSON_DIR", str(tmp_path))
    common.emit_metric("m", 1.0)
    common.flush_json("mod")
    assert os.path.exists(tmp_path / "mod.json")
    # empty-rows re-flush (the atexit path after a manual flush) stays a
    # silent no-op ...
    common.flush_json("mod")
    # ... but a second flush with NEW rows is a hard error
    common.emit_metric("m2", 2.0)
    with pytest.raises(RuntimeError, match="already written"):
        common.flush_json("mod")


def test_committed_baseline_is_loadable():
    """The repo's committed baseline must parse under the current schema."""
    doc = cbt.load_baseline(cbt.DEFAULT_BASELINE)
    assert doc["metrics"], "committed baseline has no metrics"
    for key, spec in doc["metrics"].items():
        assert "/" in key and "value" in spec
