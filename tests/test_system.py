"""System-level behaviour: the paper's three claims hold end-to-end on the
in-process cluster + calibrated simulator (see benchmarks/ for the figures).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import MachineSpec, plan
from repro.core.schedule import Job
from repro.core.simulator import (failure_latency, lmsys_like_tokens,
                                  simulate_baseline, simulate_dejavu)
from repro.models import build_model
from repro.serving import Request, ServingEngine


def test_claim1_disaggregation_improves_throughput():
    """Paper §5.2.1: up to 2× throughput vs colocated baseline."""
    cfg = PAPER_ARCHS["opt-66b"]
    wl = cm.WorkloadSpec(prompt_len=1000, new_tokens=150, microbatch=16)
    toks = lmsys_like_tokens(32, seed=0, mean_target=150)
    jobs = [Job(i, 0.0, int(t)) for i, t in enumerate(toks)]
    rb = simulate_baseline(cfg, wl, 8, jobs)
    rdv = simulate_dejavu(cfg, wl, 8, jobs)
    speedup = rb.makespan / rdv.makespan
    assert 1.2 < speedup < 3.0   # paper: up to 2×


def test_claim2_swapping_enables_bigger_batches():
    """Paper §5.2.2: microbatch swapping frees device memory for ~2× batch;
    the all-resident layout is infeasible while the 2-slot layout fits."""
    cfg = PAPER_ARCHS["opt-66b"]
    mach = MachineSpec()
    wl_big = cm.WorkloadSpec(prompt_len=1000, new_tokens=220, microbatch=64)
    p = plan(cfg, wl_big, 4, mach)
    assert not p.feasible
    resident = 2 * cfg.decode_state_bytes(1220) * wl_big.microbatch / 4
    weights = cfg.param_count() * 2 / 4
    assert resident + weights < mach.mem_bytes


def test_claim3_failure_recovery_latency():
    """Paper §5.2.3 / Fig. 14: failure slowdown 1.91× (baseline) vs 1.24×."""
    cfg = PAPER_ARCHS["opt-66b"]
    wl = cm.WorkloadSpec(prompt_len=500, new_tokens=1000, microbatch=8)
    bl = failure_latency(cfg, wl, 4, fail_step=600, dejavu=False)
    dv = failure_latency(cfg, wl, 4, fail_step=600, dejavu=True)
    assert bl["slowdown"] > 1.6
    assert dv["slowdown"] < 1.35
    assert bl["slowdown"] / dv["slowdown"] > 1.3   # paper: 1.54× latency cut


@pytest.mark.slow
def test_full_system_smoke_all_features():
    """One run with disaggregation + swapping + replication + failure."""
    cfg = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                              dtype="float32", num_layers=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (4, 8)).astype(np.int32)

    def mkreqs():
        return [Request(rid=i, prompt=prompts[i].copy(), max_new=5)
                for i in range(4)]

    ref = ServingEngine(cfg, model, params, 4, microbatch=2).run(mkreqs())
    eng = ServingEngine(cfg, model, params, 4, mode="disaggregated",
                        dp_split=(1, 3), microbatch=2, swapping=True,
                        replication=True)
    rep = eng.run(mkreqs(), fail_at={8: 2})
    assert rep.tokens == ref.tokens
    assert rep.recoveries == 1
