"""Exhaustive crash-consistency sweep (slow).

For every servable mode (per-seq vs fused rounds, disaggregated, swapping,
tiered+SSD — replication ON everywhere), record the injection-point trace of
a fault-free reference run, then re-run the same workload once per injection
point with a fault at the middle occurrence of that point
(`faults.spec_for_point`).  Every fault a correct implementation must
survive — worker death mid-replication-barrier, a dropped or corrupted
transfer, a failed SSD write, a stream-task crash, a straggler delay — has
to yield token-identical recovered output and leak zero pool/tier blocks
(`faults.assert_no_leaks`).

Set ``FAULT_SWEEP_JSON=<dir>`` to emit a per-mode coverage summary (points
seen on the reference trace vs points exercised) — CI uploads these as the
fault-coverage artifact.

A hypothesis property test additionally draws random FaultPlans (random
point, occurrence, transient kind, mode, pool pressure) and asserts the
same invariants; it skips cleanly when hypothesis is absent.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.configs.registry import PAPER_ARCHS
from repro.core.dejavulib import faults
from repro.core.dejavulib.faults import FaultInjector, FaultPlan, FaultSpec
from repro.models import build_model
from repro.serving import Request, ServingEngine

pytestmark = pytest.mark.slow

CFG = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                          dtype="float32", num_layers=4)
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
RNG = np.random.default_rng(7)
BLOCK = 8
# prompts: two share a full prefix (tiered adoption), one long (chunking),
# one short; lengths are multiples/fractions of BLOCK to hit partial blocks
_P0 = RNG.integers(0, CFG.vocab_size, 16).astype(np.int32)
_P1 = RNG.integers(0, CFG.vocab_size, 24).astype(np.int32)
_P3 = RNG.integers(0, CFG.vocab_size, 9).astype(np.int32)
PROMPTS = [_P0, _P1, _P0.copy(), _P3]
N_NEW = 4

MODES = {
    "perseq": dict(fused_rounds=False),
    "fused": dict(),
    "disagg": dict(mode="disaggregated", dp_split=(2, 2), n_workers=4),
    "swap": dict(swapping=True),
    "tiered": dict(tiered=True, kv_pool_blocks=10, host_cache_blocks=4,
                   ssd_cache_blocks=64),
}
# ring-replication successor of the victim must be alive: kill the LAST
# token worker (disagg token group is wids 2..3; colocated is 0..1)
KILL_WID = {"disagg": 3}

# sweep kind per point: worker death at the coarse boundaries, transient
# faults at the fine-grained streaming ops (faults.survivable_kinds order)
POINT_KIND = {
    "transport.transfer.net": "corrupt",
}


def _mkreqs():
    return [Request(rid=i, prompt=PROMPTS[i].copy(), max_new=N_NEW)
            for i in range(len(PROMPTS))]


def _engine(mode: str) -> ServingEngine:
    opts = dict(MODES[mode])
    n_workers = opts.pop("n_workers", 2)
    cluster_mode = opts.pop("mode", "colocated")
    dp_split = opts.pop("dp_split", None)
    return ServingEngine(CFG, MODEL, PARAMS, n_workers, mode=cluster_mode,
                         dp_split=dp_split, microbatch=1, paged=True,
                         replication=True, kv_block_size=BLOCK, **opts)


def _run(mode: str, *, injector=None, plan=None):
    eng = _engine(mode)
    rep = eng.run_continuous(_mkreqs(), max_active=3,
                             fault_injector=injector, fault_plan=plan)
    return rep, eng


_REFS = {}


def _reference(mode: str):
    """Fault-free run with a recording injector: (tokens, counts)."""
    if mode not in _REFS:
        inj = FaultInjector(record=True)
        rep, eng = _run(mode, injector=inj)
        faults.assert_no_leaks(eng.cluster)
        assert rep.failures == 0 and rep.fault_trace == []
        _REFS[mode] = (rep.tokens, dict(inj.counts))
    return _REFS[mode]


def _emit_coverage(mode: str, counts, exercised) -> None:
    out_dir = os.environ.get("FAULT_SWEEP_JSON")
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    ref = FaultInjector()
    ref.counts = dict(counts)
    summary = {"mode": mode, **faults.coverage_summary(ref, exercised)}
    with open(os.path.join(out_dir, f"{mode}.json"), "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)


@pytest.mark.parametrize("mode", sorted(MODES))
def test_crash_consistency_sweep(mode):
    """Every injection point on the reference trace, faulted at its middle
    occurrence, recovers to token-identical output with zero leaks."""
    ref_tokens, counts = _reference(mode)
    assert counts.get("engine.step", 0) > 0
    assert counts.get("stream.drain", 0) > 0       # replication barriers ran
    exercised = {}
    failures = []
    for point in sorted(counts):
        kinds = faults.survivable_kinds(point)
        if not kinds:
            continue                               # e.g. cluster.fail itself
        kind = POINT_KIND.get(point, kinds[0])
        spec = faults.spec_for_point(point, counts[point], kind,
                                     wid=KILL_WID.get(mode, 1))
        inj = FaultInjector(FaultPlan([spec]))
        try:
            rep, eng = _run(mode, injector=inj)
            assert inj.fired, f"{mode}/{point}: planned fault never fired"
            assert rep.tokens == ref_tokens, \
                f"{mode}/{point}/{kind}@{spec.nth}: tokens diverged"
            if kind == "worker_death":
                assert rep.failures == 1 and rep.recoveries >= 1
                assert rep.fault_trace[0]["point"] == point
            else:
                assert rep.failures == 0
            faults.assert_no_leaks(eng.cluster)
            exercised[point] = {"nth": spec.nth, "kind": kind, "ok": True}
        except AssertionError as e:
            exercised[point] = {"nth": spec.nth, "kind": kind, "ok": False}
            failures.append(f"{mode}/{point}/{kind}@{spec.nth}: {e}")
    _emit_coverage(mode, counts, exercised)
    assert not failures, "\n".join(failures)
    # the sweep exercised every survivable point the reference trace saw
    assert sorted(exercised) == sorted(
        p for p in counts if faults.survivable_kinds(p))


# ---------------------------------------------------------------------------
# hypothesis property test: random FaultPlans (skips cleanly if absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: f

    def settings(*a, **k):
        return lambda f: f

    class st:                                      # noqa: N801
        @staticmethod
        def data():
            return None

#: transient kinds only — worker_death is swept deterministically above,
#: so the randomized layer probes the retry/straggler space more densely
_RANDOM_KINDS = {
    "stream.task": ["task_error", "delay"],
    "stream.submit": ["delay"],
    "stream.wait": ["delay"],
    "stream.drain": [],
    "engine.step": [],
    "cluster.fail": [],
    "ssd.put": ["ssd_write"],
    "tier.demote": ["delay"],
    "tier.promote": ["delay"],
}


def _random_kinds(point):
    if point in _RANDOM_KINDS:
        return _RANDOM_KINDS[point]
    if point.startswith("transport.transfer."):
        return ["drop", "corrupt", "delay"]
    return []


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.data_too_large,
                                 HealthCheck.too_slow]
          if HAVE_HYPOTHESIS else [])
@given(data=st.data())
def test_random_fault_plans_token_identical(data):
    mode = data.draw(st.sampled_from(["fused", "perseq", "tiered"]),
                     label="mode")
    ref_tokens, counts = _reference(mode)
    candidates = sorted(p for p in counts if _random_kinds(p))
    point = data.draw(st.sampled_from(candidates), label="point")
    nth = data.draw(st.integers(1, counts[point]), label="nth")
    kind = data.draw(st.sampled_from(_random_kinds(point)), label="kind")
    delay = data.draw(st.floats(1e-4, 0.5), label="delay_s")
    spec = FaultSpec(point, nth=nth, kind=kind, delay_s=delay)
    inj = FaultInjector(FaultPlan([spec]))
    rep, eng = _run(mode, injector=inj)
    assert inj.fired, f"{point}@{nth} never fired"
    assert rep.failures == 0
    assert rep.tokens == ref_tokens
    faults.assert_no_leaks(eng.cluster)
