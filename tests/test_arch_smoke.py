"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates at REDUCED scale and runs one forward/train
step + one prefill/decode step on CPU; asserts output shapes and finiteness.
The FULL configs are exercised only via the compile-only dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.configs.registry import PAPER_ARCHS
from repro.models import build_model

pytestmark = pytest.mark.slow  # full sweep; excluded from `pytest -m "not slow"`

ALL_ARCHS = sorted(ARCHS) + sorted(PAPER_ARCHS)


def _batch(cfg, rng, b=2, s=24, train=True):
    key = jax.random.PRNGKey(7)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if train:
        batch["targets"] = batch["tokens"]
        batch["loss_mask"] = jnp.ones((b, s), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(key, (b, cfg.num_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(key, (b, 16, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_smoke(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    loss = model.loss(params, _batch(cfg, rng))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"
    # one optimizer step must keep params finite
    from repro.training import adamw_init, make_train_step
    step = make_train_step(model)
    opt = adamw_init(params)
    p2, o2, m = jax.jit(step)(params, opt, _batch(cfg, rng))
    assert bool(jnp.isfinite(m["loss"]))
    assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
               for x in jax.tree.leaves(p2))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_smoke(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    b, s = 2, 16
    batch = _batch(cfg, rng, b=b, s=s, train=False)
    logits, state, pos = model.prefill(params, batch, max_len=s + 4 + cfg.context_overhead)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, state2 = model.decode_step(params, state, tok, pos)
    assert logits2.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("name", ["smollm-360m", "mamba2-780m", "hymba-1.5b",
                                  "seamless-m4t-large-v2", "phi-3-vision-4.2b",
                                  "opt-66b", "bloom-176b", "gpt2-1.5b"])
def test_decode_matches_full_forward(name):
    """Incremental decoding with cache == teacher-forced full forward."""
    cfg = dataclasses.replace(get_arch(name).reduced(), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    b, s, extra = 2, 20, 5
    total = s + extra + cfg.context_overhead
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + extra)), jnp.int32)
    full = {"tokens": tok}
    pre = {"tokens": tok[:, :s]}
    key = jax.random.PRNGKey(3)
    if cfg.family == "vlm":
        pe = jax.random.normal(key, (b, cfg.num_patches, cfg.d_model))
        full["patch_embeds"] = pe; pre["patch_embeds"] = pe
    if cfg.family == "encdec":
        se = jax.random.normal(key, (b, 16, cfg.d_model))
        full["src_embeds"] = se; pre["src_embeds"] = se
    ref, _, _ = model.prefill(params, full, max_len=total)
    logits, state, pos = model.prefill(params, pre, max_len=total)
    for i in range(extra):
        logits, state = model.decode_step(params, state, tok[:, s + i], pos)
        pos = pos + 1
    rel = float(jnp.max(jnp.abs(logits - ref))) / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-4, f"{name}: rel err {rel}"


def test_moe_decode_matches_with_dropfree_capacity():
    """MoE: prefill/decode agree exactly when capacity can't drop (cf = E/k)."""
    cfg = dataclasses.replace(get_arch("qwen3-moe-30b-a3b").reduced(),
                              dtype="float32", moe_capacity_factor=2.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    ref, _, _ = model.prefill(params, {"tokens": tok}, max_len=24)
    logits, state, pos = model.prefill(params, {"tokens": tok[:, :20]}, max_len=24)
    for i in range(4):
        logits, state = model.decode_step(params, state, tok[:, 20 + i], pos)
        pos = pos + 1
    assert float(jnp.max(jnp.abs(logits - ref))) < 1e-4


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_param_count_close_to_nameplate(name):
    cfg = get_arch(name)
    n = cfg.param_count()
    assert n > 0
    # MoE active < total
    if cfg.is_moe:
        assert cfg.active_param_count() < n
