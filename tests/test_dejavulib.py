"""DéjàVuLib: primitives, repartitioning, transports, overlap engine."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.dejavulib import (CacheChunk, HostLinkTransport,
                                  HostMemoryStore, LocalTransport,
                                  NetworkTransport, PipelineTopo, SSDStore,
                                  StreamEngine, fetch, flush, gather,
                                  plan_repartition, scatter, stream_in,
                                  stream_out)


def test_flush_fetch_roundtrip(tmp_path):
    tr = LocalTransport()
    for store in (HostMemoryStore("h"), SSDStore(str(tmp_path))):
        arr = np.random.randn(3, 4).astype(np.float32)
        flush(arr, store, "a/b", tr)
        got = fetch(store, "a/b", tr)
        np.testing.assert_array_equal(got, arr)
        assert "a/b" in store
        store.delete("a/b")
        assert "a/b" not in store


def test_store_capacity_enforced():
    store = HostMemoryStore("cap", capacity_bytes=100)
    store.put("x", np.zeros(10, np.float32))     # 40 bytes
    with pytest.raises(MemoryError):
        store.put("y", np.zeros(32, np.float32))  # would exceed


@settings(max_examples=60, deadline=None)
@given(
    depth_src=st.integers(1, 6), depth_dst=st.integers(1, 6),
    layers=st.integers(6, 24),
    mb_src=st.sampled_from([1, 2, 4, 8]), mb_dst=st.sampled_from([1, 2, 4, 8]),
)
def test_plan_repartition_is_exact_partition(depth_src, depth_dst, layers,
                                             mb_src, mb_dst):
    """The repartition plan covers every (layer, batch-element) of the
    destination exactly once — no gaps, no overlaps (stream_out contract)."""
    src = PipelineTopo(depth_src, layers, mb_src)
    dst = PipelineTopo(depth_dst, layers, mb_dst)
    plan = plan_repartition(src, dst)
    nb = max(mb_src, mb_dst)
    cover = np.zeros((layers, nb), np.int32)
    for ss, ds, lr, br in plan:
        # chunk must be inside both stages' ownership
        slo, shi = src.layer_range(ss)
        dlo, dhi = dst.layer_range(ds)
        assert slo <= lr[0] and lr[1] <= shi
        assert dlo <= lr[0] and lr[1] <= dhi
        cover[lr[0]:lr[1], br[0]:br[1]] += 1
    assert (cover == 1).all()


@settings(max_examples=20, deadline=None)
@given(depth_src=st.integers(1, 4), depth_dst=st.integers(1, 4),
       layers=st.integers(4, 12))
def test_stream_out_in_roundtrip(depth_src, depth_dst, layers):
    L, B, S, H, D = layers, 2, 8, 2, 4
    state = {"kv": {"k": np.random.randn(L, B, S, H, D).astype(np.float32)}}
    src = PipelineTopo(depth_src, L, B)
    dst = PipelineTopo(depth_dst, L, B)
    tr = NetworkTransport()
    stores = {i: HostMemoryStore(f"t{i}") for i in range(depth_dst)}
    for ss in range(depth_src):
        lo, hi = src.layer_range(ss)
        stream_out({"kv": {"k": state["kv"]["k"][lo:hi]}}, ss, src, dst,
                   stores, tr, mb=0, token_range=(0, S))
    for ds in range(depth_dst):
        lo, hi = dst.layer_range(ds)
        shapes = {"kv": {"k": ((hi - lo, B, S, H, D), "float32")}}
        local = stream_in(stores[ds], ds, dst, src, shapes, tr, mb=0,
                          token_range=(0, S))
        np.testing.assert_allclose(local["kv"]["k"], state["kv"]["k"][lo:hi])


def test_buffered_scatter_beats_baseline_latency():
    """Paper Fig. 11: buffered copies amortize per-transfer latency."""
    L, B, S, H, D = 16, 2, 32, 2, 8
    cache = jnp.asarray(np.random.randn(L, B, S, H, D).astype(np.float32))
    tr = HostLinkTransport()
    scatter(cache, "kv/k", (8, 9), HostMemoryStore(), tr, buffered=True)
    t_buf = tr.modeled_total()
    tr.reset_log()
    scatter(cache, "kv/k", (8, 9), HostMemoryStore(), tr, buffered=False)
    t_base = tr.modeled_total()
    assert t_base / t_buf > 5.0   # ~L transfers' latency amortized into one


def test_scatter_gather_roundtrip():
    L, B, S, H, D = 4, 2, 32, 2, 8
    cache = jnp.asarray(np.random.randn(L, B, S, H, D).astype(np.float32))
    store = HostMemoryStore()
    tr = LocalTransport()
    scatter(cache, "kv/k", (8, 16), store, tr, buffered=True)
    chunks = [CacheChunk("kv/k", (0, L), (0, B), (8, 16))]
    out = gather(store, "kv/k", (L, B, S, H, D), np.float32, chunks, tr)
    np.testing.assert_allclose(out[:, :, 8:16], np.asarray(cache)[:, :, 8:16])
    assert (out[:, :, :8] == 0).all() and (out[:, :, 16:] == 0).all()


def test_stream_engine_overlap_accounting():
    eng = StreamEngine("t")
    results = [eng.submit(lambda i=i: i * i, model_seconds=0.5, tag=f"t{i}")
               for i in range(4)]
    assert [eng.wait(t) for t in results] == [0, 1, 4, 9]
    eng.compute_span(1.2)
    rep = eng.overlap_report()
    assert rep["stream_s"] == pytest.approx(2.0)
    assert rep["hidden_s"] == pytest.approx(1.2)
    assert rep["exposed_s"] == pytest.approx(0.8)
    eng.close()


def test_stream_engine_propagates_errors():
    eng = StreamEngine("err")
    t = eng.submit(lambda: 1 / 0, tag="boom")
    with pytest.raises(ZeroDivisionError):
        eng.wait(t)
    eng.close()


def test_ssd_store_atomic_and_persistent(tmp_path):
    store = SSDStore(str(tmp_path))
    arr = np.arange(10, dtype=np.int64)
    store.put("rep/mb0/k", arr)
    # a new store object over the same dir sees the data (process restart)
    store2 = SSDStore(str(tmp_path))
    np.testing.assert_array_equal(store2.get("rep/mb0/k"), arr)
    assert store2.used_bytes() > 0
