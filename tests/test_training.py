"""Training substrate: optimizer, data determinism, checkpoint fault tolerance."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS
from repro.models import build_model
from repro.training import (SyntheticDataPipeline, adamw_init, latest_step,
                            make_train_step, restore_checkpoint, save_checkpoint)
from repro.training.optimizer import global_norm, quantize_int8
from repro.training.train import TrainConfig

CFG = dataclasses.replace(ARCHS["smollm-360m"].reduced(), dtype="float32")
MODEL = build_model(CFG)


def _pipeline(batch=8, seq=32):
    return SyntheticDataPipeline(CFG.vocab_size, seq, batch, seed=1)


@pytest.mark.slow
def test_loss_decreases():
    params = MODEL.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    data = _pipeline()
    step_fn = jax.jit(make_train_step(MODEL, TrainConfig(lr=1e-3)))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1
    assert all(np.isfinite(losses))


def test_grad_accum_matches_full_batch():
    params = MODEL.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {k: jnp.asarray(v) for k, v in _pipeline(batch=8).batch_at(0).items()}
    p1, _, m1 = jax.jit(make_train_step(MODEL, TrainConfig(lr=1e-3)))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(MODEL, TrainConfig(lr=1e-3, grad_accum=4)))(
        params, opt, batch)
    # same data, same total gradient (mean over microbatches == full batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-5


def test_remat_matches_no_remat():
    m_plain = build_model(CFG, remat=False)
    m_remat = build_model(CFG, remat=True)
    params = m_plain.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in _pipeline().batch_at(0).items()}
    g1 = jax.grad(m_plain.loss)(params, batch)
    g2 = jax.grad(m_remat.loss)(params, batch)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert d < 1e-5


def test_data_pipeline_deterministic_and_host_sharded():
    d1 = SyntheticDataPipeline(256, 16, 8, seed=3, host_id=0, num_hosts=2)
    d2 = SyntheticDataPipeline(256, 16, 8, seed=3, host_id=0, num_hosts=2)
    d3 = SyntheticDataPipeline(256, 16, 8, seed=3, host_id=1, num_hosts=2)
    np.testing.assert_array_equal(d1.batch_at(5)["tokens"], d2.batch_at(5)["tokens"])
    assert not np.array_equal(d1.batch_at(5)["tokens"], d3.batch_at(5)["tokens"])
    assert d1.batch_at(0)["tokens"].shape == (4, 16)   # local shard


def test_checkpoint_roundtrip_and_resume(tmp_path):
    params = MODEL.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    data = _pipeline()
    step_fn = jax.jit(make_train_step(MODEL, TrainConfig(lr=1e-3)))
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, _ = step_fn(params, opt, batch)
    save_checkpoint(str(tmp_path), 3, {"params": params, "opt": opt})
    restored, step = restore_checkpoint(str(tmp_path), {"params": params, "opt": opt})
    assert step == 3
    for a, b in zip(jax.tree.leaves(restored),
                    jax.tree.leaves({"params": params, "opt": opt})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # continuing from the restore is bit-identical to continuing in-process
    p1, o1 = params, opt
    p2, o2 = restored["params"], restored["opt"]
    for i in range(3, 6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        p1, o1, m1 = step_fn(p1, o1, batch)
        p2, o2, m2 = step_fn(p2, o2, batch)
    assert float(m1["loss"]) == float(m2["loss"])


def test_checkpoint_atomicity_torn_save_invisible(tmp_path):
    params = MODEL.init(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, {"params": params})
    # simulate a torn save: a .tmp dir without manifest
    torn = tmp_path / "step_00000002.tmp"
    torn.mkdir()
    (torn / "garbage.npy").write_bytes(b"xx")
    assert latest_step(str(tmp_path)) == 1
    restored, step = restore_checkpoint(str(tmp_path), {"params": params})
    assert step == 1


def test_checkpoint_gc_keeps_last_k(tmp_path):
    params = {"w": jnp.ones((4,))}
    for s in range(1, 6):
        save_checkpoint(str(tmp_path), s, params, keep=2)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000004", "step_00000005"]


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1, max_size=64))
def test_int8_quantization_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, scale = quantize_int8(x)
    deq = q.astype(jnp.float32) * scale
    # error per element bounded by half a quantization step
    assert float(jnp.max(jnp.abs(deq - x))) <= float(scale) * 0.5 + 1e-6


def test_compressed_allreduce_close_to_exact():
    from repro.training.optimizer import compressed_allreduce
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("dp",), axis_types=(jax.sharding.AxisType.Auto,))
    f = jax.jit(jax.shard_map(lambda x: compressed_allreduce(x, "dp"),
                              mesh=mesh, in_specs=P("dp"), out_specs=P(),
                              check_vma=False))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 256)), jnp.float32)
    out = f(x)
    rel = float(jnp.max(jnp.abs(out - x.sum(0)))) / float(jnp.max(jnp.abs(x)))
    assert rel < 0.01


def test_global_norm():
    tree = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(tree)) == pytest.approx(np.sqrt(3 + 16))
