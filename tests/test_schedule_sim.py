"""Round-robin schedule + simulator invariants (hypothesis property tests)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import MachineSpec, plan
from repro.core.schedule import Job, rr_schedule
from repro.core.simulator import (failure_latency, lmsys_like_tokens,
                                  poisson_arrivals, simulate_baseline,
                                  simulate_dejavu, simulate_dp)

CFG = PAPER_ARCHS["opt-66b"]
MACH = MachineSpec()


@settings(max_examples=30, deadline=None)
@given(depth=st.integers(1, 5), njobs=st.integers(1, 8),
       p=st.floats(0.1, 2.0), t=st.floats(0.01, 0.2),
       seed=st.integers(0, 5))
def test_rr_schedule_invariants(depth, njobs, p, t, seed):
    rng = np.random.default_rng(seed)
    jobs = [Job(i, float(rng.random() * 2), int(rng.integers(1, 6)))
            for i in range(njobs)]
    tr, items = rr_schedule(jobs, pipeline="m", depth=depth, p_dur=p, t_dur=t)
    # (1) per-stage intervals never overlap
    per_stage = {}
    for it in items:
        per_stage.setdefault(it.stage, []).append(
            (tr.start[it.key], tr.finish[it.key]))
    for ivs in per_stage.values():
        ivs.sort()
        for (s1, f1), (s2, f2) in zip(ivs, ivs[1:]):
            assert s2 >= f1 - 1e-9
    # (2) activation deps: stage s starts after stage s-1 finishes
    for it in items:
        if it.stage > 0:
            prev = (it.pipeline, it.mb, it.kind, it.step, it.stage - 1)
            assert tr.start[it.key] >= tr.finish[prev] - 1e-9
    # (3) sampled-token dep: T_i at stage 0 after T_{i-1} at last stage
    for it in items:
        if it.kind == "T" and it.stage == 0 and it.step > 0:
            prev = (it.pipeline, it.mb, "T", it.step - 1, depth - 1)
            assert tr.start[it.key] >= tr.finish[prev] - 1e-9
    # (4) every job fully scheduled
    for j in jobs:
        assert (("m", j.mb, "T", j.n_tokens - 1, depth - 1) in tr.finish)


def _jobs(n=24, seed=0, mean=150):
    toks = lmsys_like_tokens(n, seed=seed, mean_target=mean)
    return [Job(i, 0.0, int(toks[i])) for i in range(n)]


def test_dejavu_beats_baseline_in_early_stop_regime():
    """Paper Fig. 12 regime: variable-length outputs cause prompt-injection
    bubbles in the colocated baseline; disaggregation removes them."""
    wl = cm.WorkloadSpec(prompt_len=1000, new_tokens=150, microbatch=16)
    jobs = _jobs(32, mean=150)
    rb = simulate_baseline(CFG, wl, 8, jobs, MACH)
    rdv = simulate_dejavu(CFG, wl, 8, jobs, MACH)
    assert rdv.makespan < rb.makespan
    assert rb.makespan / rdv.makespan > 1.3


def test_dp_between_baseline_and_dejavu():
    wl = cm.WorkloadSpec(prompt_len=1000, new_tokens=150, microbatch=16)
    jobs = _jobs(32, mean=150)
    rb = simulate_baseline(CFG, wl, 8, jobs, MACH)
    rdp = simulate_dp(CFG, wl, 8, 2, jobs, MACH)
    assert rdp.makespan < rb.makespan


def test_failure_latency_dejavu_much_cheaper():
    """Figs. 4/14: baseline restarts from scratch; DéjàVu resumes from the
    last replicated token."""
    wl = cm.WorkloadSpec(prompt_len=500, new_tokens=1000, microbatch=8)
    f_dv = failure_latency(CFG, wl, 4, fail_step=500, dejavu=True)
    f_bl = failure_latency(CFG, wl, 4, fail_step=500, dejavu=False)
    assert f_dv["slowdown"] < f_bl["slowdown"]
    assert f_dv["slowdown"] < 1.5           # paper: 1.24×
    assert f_bl["slowdown"] > 1.5           # paper: 1.91×


def test_lmsys_trace_deterministic():
    a = lmsys_like_tokens(100, seed=3)
    b = lmsys_like_tokens(100, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 8 and a.max() <= 1024


def test_poisson_arrivals_monotone():
    arr = poisson_arrivals(50, rate=2.0, seed=1)
    assert (np.diff(arr) > 0).all()
