"""Tiered KV-cache hierarchy (HBM→host→SSD) vs the PR-1 flat pool.

A shared-system-prompt trace (every request = common system prefix + unique
tail, the dominant production pattern) is served two ways:

1. *Measured* (reduced gpt2, real engine): `ServingEngine.run_continuous`
   with the flat paged pool vs `tiered=True`.  Sequential admission
   (`max_active=1`) isolates CROSS-REQUEST reuse: each request retires —
   dropping its pool blocks and hash index entries — before the next one
   arrives, so every prefix hit must be served by streaming blocks back out
   of the host/SSD tiers.  Greedy outputs are asserted bit-identical; the
   headline number is prefill-token savings (target ≥ 30%).  A second,
   host-starved run (tier-1 capacity 1 block) forces the same hits through
   SSD promotions.  Stall/prefetch/write-behind come from the tier managers'
   modeled accounting, and the hidden fraction from the StreamEngine
   overlap report.

2. *Modeled* (opt-66b scale): the planner's tiered terms — effective prompt
   time under `prefix_reuse_prefill_time` at the measured hit rate, and the
   token-depth relief from `tiered_token_kv_bytes` (host/SSD absorb the
   cold tail of the live KV).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, emit_metric
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.dejavulib.transport import DEFAULT_HW
from repro.core.planner import MachineSpec, TierSpec, min_token_depth, plan

N_REQUESTS = 8
SYS_PROMPT_LEN = 24        # shared system prefix (3 full 8-token blocks)
TAIL_LEN = 8               # unique per-request suffix
MAX_NEW = 6


def _trace(cfg, seed: int = 0):
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, (SYS_PROMPT_LEN,)).astype(np.int32)
    prompts = [np.concatenate([sys_prompt,
                               rng.integers(0, cfg.vocab_size,
                                            (TAIL_LEN,)).astype(np.int32)])
               for _ in range(N_REQUESTS)]
    return prompts


def measured_study():
    import jax
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                              dtype="float32", num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _trace(cfg)

    def mkreqs():
        return [Request(rid=i, prompt=p.copy(), max_new=MAX_NEW)
                for i, p in enumerate(prompts)]

    base = ServingEngine(cfg, model, params, 2, paged=True, kv_pool_blocks=128)
    rb = base.run_continuous(mkreqs(), max_active=1)

    tier = ServingEngine(cfg, model, params, 2, paged=True, tiered=True,
                         kv_pool_blocks=128, host_cache_blocks=16,
                         ssd_cache_blocks=64)
    rt = tier.run_continuous(mkreqs(), max_active=1)

    assert rb.tokens == rt.tokens, "tiered outputs diverged from baseline"
    # adoption-suffix-speed gate: every adopted prompt's unmatched suffix must
    # complete in ceil(suffix / prefill_chunk_tokens) chunked pipeline passes
    # (one per suffix token before the chunked paged-prefill kernel)
    ck = max(cfg.prefill_chunk_tokens, 1)
    log = tier.cluster.adoption_suffix_log
    assert log, "no prefix adoptions happened — the reuse trace broke"
    assert all(p <= -(-s // ck) for s, p in log), (
        f"adopted suffixes exceeded the chunked pass bound: {log}")
    emit("tiered_adoption_suffix_passes", 0.0,
         f"{sum(p for _, p in log)} passes for "
         f"{sum(s for s, _ in log)} suffix tokens (chunk={ck})")
    saved_frac = rt.prefill_tokens_saved / rt.prefill_tokens_total
    ts = rt.tier_stats
    hit_blocks = ts.get("host_hits", 0) + ts.get("ssd_hits", 0)
    miss_blocks = ts.get("demotions", 0)     # every demoted block was a miss once
    hit_rate = hit_blocks / max(hit_blocks + miss_blocks, 1)
    overlap = tier.cluster.streamer.overlap_report()
    emit_metric("tiered_prefill_saved_frac", saved_frac,
                f"{rt.prefill_tokens_saved}/{rt.prefill_tokens_total} "
                f"prefill tokens skipped via prefix adoption (gate >= 0.30)")
    emit_metric("tiered_prefix_block_hit_rate", hit_rate,
                f"{hit_blocks} hit / {miss_blocks} miss blocks")
    emit("tiered_stall_model_us", 0.0, f"{ts.get('stall_model_s', 0) * 1e6:.1f}")
    emit("tiered_prefetch_model_us", 0.0,
         f"{ts.get('prefetch_model_s', 0) * 1e6:.1f}")
    emit("tiered_stream_hidden_fraction", 0.0,
         f"{overlap['hidden_s'] / overlap['stream_s']:.0%}"
         if overlap["stream_s"] else "n/a")
    emit("tiered_transfer_bytes", 0.0,
         str({k: v for k, v in sorted(tier.transfer_summary().items()) if v}))

    # host-starved variant: tier 1 holds one block, so reuse must promote
    # through SSD — same tokens, same savings, deeper stalls
    ssd_eng = ServingEngine(cfg, model, params, 2, paged=True, tiered=True,
                            kv_pool_blocks=128, host_cache_blocks=1,
                            ssd_cache_blocks=64)
    rs = ssd_eng.run_continuous(mkreqs(), max_active=1)
    assert rb.tokens == rs.tokens, "SSD-tier outputs diverged from baseline"
    assert rs.tier_stats.get("ssd_hits", 0) > 0, "expected SSD promotions"
    emit("tiered_ssd_hits_host_starved", 0.0,
         f"{rs.tier_stats['ssd_hits']:.0f} blocks "
         f"(spills={rs.tier_stats.get('spills', 0):.0f})")
    return saved_frac, hit_rate


def modeled_study(hit_rate: float):
    cfg = PAPER_ARCHS["opt-66b"]
    mach = MachineSpec()
    d = 8
    tiers = TierSpec(host_blocks=4096, ssd_blocks=16384)
    # prompt-bound regime (long shared contexts, short answers — the RAG /
    # system-prompt serving pattern): here I_p binds, so replacing prefill
    # compute with stage-parallel block promotion moves the bottleneck
    wl_p = cm.WorkloadSpec(prompt_len=3000, new_tokens=32, microbatch=8)
    flat = plan(cfg, wl_p, d, mach, paged=True)
    tiered = plan(cfg, wl_p, d, mach, paged=True, tiers=tiers,
                  prefix_hit_rate=hit_rate, prefix_src_tier=1)
    emit("tiered_modeled_inv_tp_flat_s", 0.0, f"{flat.inv_tp_disagg:.3f}")
    emit("tiered_modeled_inv_tp_tiered_s", 0.0, f"{tiered.inv_tp_disagg:.3f}")
    if tiered.inv_tp_disagg and tiered.inv_tp_disagg != float("inf"):
        emit("tiered_modeled_throughput_ratio", 0.0,
             f"{flat.inv_tp_disagg / tiered.inv_tp_disagg:.2f}x")
    # memory axis: host/SSD-backed capacity shrinks the token-side HBM
    # requirement (Eq. 2's K_0 -> hot working set) at a KV-heavy workload
    wl_m = cm.WorkloadSpec(prompt_len=200, new_tokens=2000, microbatch=32)
    dt_flat = min_token_depth(cfg, wl_m, mach, paged=True)
    dt_tier = min_token_depth(cfg, wl_m, mach, paged=True, tiers=tiers)
    emit("tiered_modeled_min_token_depth", 0.0,
         f"{dt_flat} flat -> {dt_tier} tiered")
    emit("tiered_modeled_promotion_ms_host", 0.0,
         f"{cm.promotion_time(cfg, 1, 1) * 1e3:.2f}")
    emit("tiered_modeled_promotion_ms_ssd", 0.0,
         f"{cm.promotion_time(cfg, 1, 2) * 1e3:.2f}")
    assert dt_tier <= dt_flat or dt_flat < 0


def run() -> None:
    saved_frac, hit_rate = measured_study()
    assert saved_frac >= 0.30, (
        f"cross-request prefix reuse saved only {saved_frac:.0%} of prefill "
        f"tokens (< 30%)")
    modeled_study(hit_rate)


if __name__ == "__main__":
    run()
