"""Paper Figs. 4/14/15: failure impact on latency and completions.

Fig. 14: cumulative latency of one microbatch with a stage failure at a given
token step — restart-from-scratch vs DéjàVu replica recovery.
Fig. 15: request completions over time with failures injected at 600/1200/
1800 s — total runtime ratio (paper: 1.16× shorter with DéjàVu).
Also runs the REAL in-process cluster with an injected failure and verifies
recovery work (steps redone) stays at the replication lag.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from benchmarks.common import emit, emit_metric
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core import exporters, telemetry, tracing
from repro.core.simulator import failure_latency
from repro.models import build_model
from repro.serving import Request, ServingEngine
from tools import trace_report


def run() -> None:
    cfg = PAPER_ARCHS["opt-66b"]
    wl = cm.WorkloadSpec(500, 1000, 8)
    for step in (250, 500, 750):
        bl = failure_latency(cfg, wl, 4, fail_step=step, dejavu=False)
        dv = failure_latency(cfg, wl, 4, fail_step=step, dejavu=True)
        emit(f"fig14/opt-66b/fail@{step}/baseline_slowdown",
             bl["slowdown"] * 1e6, f"{bl['slowdown']:.2f}x (paper 1.91x)")
        emit(f"fig14/opt-66b/fail@{step}/dejavu_slowdown",
             dv["slowdown"] * 1e6, f"{dv['slowdown']:.2f}x (paper 1.24x)")
        cut = bl["slowdown"] / dv["slowdown"]
        emit_metric(f"fig14_latency_cut_fail{step}", cut, "(paper 1.54x)")
        # headline invariant: replica recovery beats restart-from-scratch
        assert cut > 1.0, (
            f"fail@{step}: DejaVu recovery slowdown {dv['slowdown']:.2f}x "
            f">= baseline restart {bl['slowdown']:.2f}x")

    # Fig. 15: 3 failures across a long serving trace -> total runtime ratio.
    # Each failure costs (redo of in-flight work + restart) for the baseline
    # vs (replication-lag redo + replica restore) for DéjàVu, added to the
    # failure-free trace makespan.
    wl15 = cm.WorkloadSpec(500, 1000, 8)
    bl1 = failure_latency(cfg, wl15, 4, fail_step=500, dejavu=False)
    dv1 = failure_latency(cfg, wl15, 4, fail_step=500, dejavu=True)
    t0 = 40 * bl1["no_fail_s"] / 4          # ~40 microbatches, 4-deep pipeline
    extra_bl = bl1["with_fail_s"] - bl1["no_fail_s"]   # per-failure overhead
    extra_dv = dv1["with_fail_s"] - dv1["no_fail_s"]
    ratio = (t0 + 3 * extra_bl) / (t0 + 3 * extra_dv)
    emit_metric("fig15_trace_ratio", ratio,
                f"{ratio:.2f}x shorter trace with DejaVu (paper 1.16x)")
    assert ratio > 1.0, f"fig15: trace ratio {ratio:.2f}x <= 1x"

    # real-cluster recovery: tokens identical, redone work == replication lag
    rcfg = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                               dtype="float32", num_layers=8)
    model = build_model(rcfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, rcfg.vocab_size, (4, 8)).astype(np.int32)

    def reqs():
        return [Request(rid=i, prompt=prompts[i].copy(), max_new=6)
                for i in range(4)]

    ref = ServingEngine(rcfg, model, params, 4, microbatch=2).run(reqs())
    eng = ServingEngine(rcfg, model, params, 4, microbatch=2, replication=True)
    rep = eng.run(reqs(), fail_at={9: 2})
    emit("fig15/real_cluster/tokens_identical",
         float(rep.tokens == ref.tokens) * 1e6,
         f"recoveries={rep.recoveries} redone_steps={rep.steps_redone}")
    # headline invariants on the real cluster: recovered tokens are
    # bit-identical and the recovery-time span is populated and bounded
    assert rep.tokens == ref.tokens, "post-recovery tokens diverged"
    assert rep.recoveries == 1, f"expected 1 recovery, got {rep.recoveries}"
    rec = rep.telemetry["histograms"].get("cluster.recovery_s")
    assert rec is not None and rec["count"] >= 1, \
        "cluster.recovery_s span missing from telemetry"
    emit_metric("failures_recovery_model_s_max", rec["max_s"],
                "fail -> first post-restore token, modeled clock")
    assert rec["max_s"] < 60.0, \
        f"recovery time {rec['max_s']:.1f}s unbounded on the modeled clock"

    _export_trace_artifacts(rcfg, model, params, prompts)


def _export_trace_artifacts(rcfg, model, params, prompts) -> None:
    """Flight-recorder export: run the continuous-batching engine with a
    tracer installed and an injected worker death, and write the raw
    ``repro.trace/v1`` dump plus its Perfetto and Prometheus renderings
    into ``$BENCH_JSON_DIR``.  CI uploads these as workflow artifacts and
    gates ``tools/trace_report.py --assert`` on the dump; without
    ``BENCH_JSON_DIR`` only the coverage row is emitted."""
    tracer = tracing.Tracer()
    prev_trace = tracing.install(tracer)
    tele = telemetry.Telemetry()
    prev_tele = telemetry.install(tele)
    try:
        eng = ServingEngine(rcfg, model, params, 2, paged=True, tiered=True,
                            kv_pool_blocks=128, host_cache_blocks=16,
                            ssd_cache_blocks=32, replication=True)
        reqs = [Request(rid=i, prompt=prompts[i].copy(), max_new=6)
                for i in range(4)]
        rep = eng.run_continuous(reqs, max_active=2, fail_at={5: 1})
        assert rep.recoveries == 1, \
            f"traced run: expected 1 recovery, got {rep.recoveries}"
        trace_json = tracer.to_json()
        trace = json.loads(trace_json)
        snapshot = tele.snapshot()
    finally:
        telemetry.uninstall(prev_tele)
        tracing.uninstall(prev_trace)

    report = trace_report.analyze(trace)
    cov = min(r["coverage"] for r in report["requests"].values())
    emit_metric("failures_trace_min_coverage", cov,
                "min per-request named-phase coverage of the traced run")
    assert cov >= 0.95, f"traced run coverage {cov:.4f} < 0.95"

    out_dir = os.environ.get("BENCH_JSON_DIR")
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "failures_trace.json"), "w",
              encoding="utf-8") as f:
        f.write(trace_json)
    with open(os.path.join(out_dir, "failures_trace.perfetto.json"), "w",
              encoding="utf-8") as f:
        f.write(exporters.dumps(exporters.trace_to_perfetto(trace)))
    with open(os.path.join(out_dir, "failures_prometheus.prom"), "w",
              encoding="utf-8") as f:
        f.write(exporters.telemetry_to_prometheus(snapshot))


if __name__ == "__main__":
    run()
