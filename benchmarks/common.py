"""Shared benchmark helpers: CSV emission + default scenario constants."""
from __future__ import annotations

import time
from typing import Iterable


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def timeit(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6   # µs
