"""Shared benchmark helpers: CSV emission (+ JSON artifact capture) and
timing.

Every `emit` row is also recorded in memory; when the ``BENCH_JSON_DIR``
environment variable is set, the rows are written at interpreter exit to
``$BENCH_JSON_DIR/<script-stem>.json`` so CI can upload the per-PR perf
trajectory as a workflow artifact without re-running anything.
"""
from __future__ import annotations

import atexit
import json
import os
import sys
import time

_ROWS: list = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    _ROWS.append({"name": name, "us_per_call": us_per_call, "derived": derived})


def timeit(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6   # µs


def flush_json(name: str) -> None:
    """Write (and clear) the rows emitted so far to ``$BENCH_JSON_DIR/
    <name>.json``.  The `benchmarks.run` harness calls this after each
    module so the full-suite job still produces per-module artifacts; a
    directly-invoked module relies on the atexit hook below instead."""
    out_dir = os.environ.get("BENCH_JSON_DIR")
    if not out_dir:
        _ROWS.clear()
        return
    if not _ROWS:
        return
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w", encoding="utf-8") as f:
        json.dump(_ROWS, f, indent=1)
    _ROWS.clear()


def _write_json_rows() -> None:
    stem = os.path.splitext(os.path.basename(sys.argv[0]))[0] or "bench"
    flush_json(stem)


atexit.register(_write_json_rows)
