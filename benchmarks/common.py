"""Shared benchmark helpers: CSV emission (+ JSON artifact capture) and
timing.

Every `emit`/`emit_metric` row is also recorded in memory; when the
``BENCH_JSON_DIR`` environment variable is set, the rows are written (at
interpreter exit, or per-module via `flush_json`) to
``$BENCH_JSON_DIR/<script-stem>.json`` so CI can upload the per-PR perf
trajectory as a workflow artifact without re-running anything.

The JSON artifact is the ``repro.bench/v1`` schema::

    {"schema": "repro.bench/v1",
     "rows": [{"name": ..., "us_per_call": ..., "derived": ...},
              {"name": ..., "value": <float>, "note": ...}, ...],
     "telemetry": <repro.telemetry/v1 snapshot or null>}

`emit_metric` rows carry a NUMERIC ``value`` — these are what
``tools/check_bench_trend.py`` compares against the committed baseline
(``benchmarks/baselines/BENCH_baseline.json``).  When ``BENCH_JSON_DIR``
is set, an ambient telemetry registry is installed at import so every
`ServingEngine` run in the module aggregates into one snapshot, embedded
in the artifact at flush time.
"""
from __future__ import annotations

import atexit
import json
import os
import sys
import time

from repro.core import telemetry

_ROWS: list = []
_FLUSHED: set = set()   # stems written this process (double-flush guard)

if os.environ.get("BENCH_JSON_DIR") and telemetry.current() is None:
    telemetry.install(telemetry.Telemetry())


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    _ROWS.append({"name": name, "us_per_call": us_per_call, "derived": derived})


def emit_metric(name: str, value: float, note: str = "") -> None:
    """A numeric headline metric (trend-gated by check_bench_trend.py)."""
    print(f"{name},{float(value):.6g},{note}")
    _ROWS.append({"name": name, "value": float(value), "note": note})


def timeit(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6   # µs


def flush_json(name: str) -> None:
    """Write (and clear) the rows emitted so far to ``$BENCH_JSON_DIR/
    <name>.json``, embedding the ambient telemetry snapshot (a fresh
    registry is installed afterwards so modules don't bleed into each
    other).  The `benchmarks.run` harness calls this after each module so
    the full-suite job still produces per-module artifacts; a
    directly-invoked module relies on the atexit hook below instead."""
    out_dir = os.environ.get("BENCH_JSON_DIR")
    if not out_dir:
        _ROWS.clear()
        return
    if not _ROWS:
        return
    if name in _FLUSHED:
        # a second flush would silently overwrite the artifact (rows and
        # telemetry already cleared), corrupting the CI trend input —
        # error out rather than lose the first flush's numbers
        raise RuntimeError(
            f"flush_json({name!r}): artifact already written this process; "
            "a module must flush each stem at most once")
    _FLUSHED.add(name)
    tele = telemetry.current()
    doc = {
        "schema": "repro.bench/v1",
        "rows": list(_ROWS),
        "telemetry": tele.snapshot() if tele is not None else None,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    _ROWS.clear()
    if tele is not None:
        telemetry.install(telemetry.Telemetry())


def _write_json_rows() -> None:
    stem = os.path.splitext(os.path.basename(sys.argv[0]))[0] or "bench"
    flush_json(stem)


atexit.register(_write_json_rows)
