"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
``python -m benchmarks.run [--only fig12]``.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    ("fig2_prompt_vs_token", "benchmarks.prompt_vs_token"),
    ("fig11_streaming_breakdown", "benchmarks.streaming_breakdown"),
    ("fig12_e2e_disagg", "benchmarks.e2e_disagg"),
    ("fig13_swapping", "benchmarks.swapping"),
    ("fig14_15_failures", "benchmarks.failures"),
    ("appB_planner_study", "benchmarks.planner_study"),
    ("continuous_batching", "benchmarks.continuous_batching"),
    ("tiered_kv", "benchmarks.tiered_kv"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter over benchmark names")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, modpath in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        mod = __import__(modpath, fromlist=["run"])
        try:
            mod.run()
        except Exception as e:  # keep the harness going, report the failure
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stdout)
        print(f"{name}/total_s,{(time.time()-t0)*1e6:.0f},", flush=True)


if __name__ == "__main__":
    main()
