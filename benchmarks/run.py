"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
``python -m benchmarks.run [--only fig12]``.  A failing sub-benchmark gate
(assertion or crash) is reported inline, the remaining modules still run,
and the process exits non-zero so CI fails on any regressed gate.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import flush_json

MODULES = [
    ("fig2_prompt_vs_token", "benchmarks.prompt_vs_token"),
    ("fig11_streaming_breakdown", "benchmarks.streaming_breakdown"),
    ("fig12_e2e_disagg", "benchmarks.e2e_disagg"),
    ("fig13_swapping", "benchmarks.swapping"),
    ("fig14_15_failures", "benchmarks.failures"),
    ("appB_planner_study", "benchmarks.planner_study"),
    ("continuous_batching", "benchmarks.continuous_batching"),
    ("tiered_kv", "benchmarks.tiered_kv"),
    ("chunked_prefill", "benchmarks.chunked_prefill"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter over benchmark names")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for name, modpath in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = __import__(modpath, fromlist=["run"])
            mod.run()
        except Exception as e:  # keep the harness going, report the failure
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stdout)
            failed.append(name)
        print(f"{name}/total_s,{(time.time()-t0)*1e6:.0f},", flush=True)
        # per-module JSON artifact even under -m run; keyed by the module's
        # script stem so trend baselines match direct invocation
        flush_json(modpath.rsplit(".", 1)[-1])
    if failed:
        print(f"FAILED gates: {', '.join(failed)}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
