"""Kernel microbenchmarks (wall time, CPU interpret mode).

Interpret-mode timings validate the harness, not TPU performance — the
TPU-relevant numbers are the §Roofline terms from the compiled dry-run.
Includes the kv_pack buffered-copy dispatch-count comparison that is
hardware-independent: one kernel launch vs 2·L slice copies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kv_pack import kv_pack


def run() -> None:
    key = jax.random.PRNGKey(0)
    # flash attention vs reference
    b, s, hq, hkv, d = 1, 128, 4, 2, 32
    q = jax.random.normal(key, (b, s, hq, d))
    k = jax.random.normal(key, (b, s, hkv, d))
    v = jax.random.normal(key, (b, s, hkv, d))
    f1 = jax.jit(lambda q, k, v: flash_attention(q, k, v, block_q=64, block_k=64))
    f2 = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    emit("kernels/flash_attention_interp_us", timeit(f1, q, k, v), "interpret-mode")
    emit("kernels/flash_attention_ref_us", timeit(f2, q, k, v), "jnp-oracle")

    # decode attention
    q1 = jax.random.normal(key, (2, hq, d))
    kc = jax.random.normal(key, (2, 512, hkv, d))
    vc = jax.random.normal(key, (2, 512, hkv, d))
    valid = jnp.ones((512,), bool)
    g1 = jax.jit(lambda q, k, v: decode_attention(q, k, v, valid, block_k=256))
    g2 = jax.jit(lambda q, k, v: ref.decode_attention_ref(q, k, v, valid))
    emit("kernels/decode_attention_interp_us", timeit(g1, q1, kc, vc), "")
    emit("kernels/decode_attention_ref_us", timeit(g2, q1, kc, vc), "")

    # kv_pack: ONE launch covers what 2·L non-contiguous copies would
    L, B, S, H, D = 32, 4, 256, 8, 64
    cache = jax.random.normal(key, (L, B, S, H, D), jnp.bfloat16)
    p1 = jax.jit(lambda c: kv_pack(c, 128, width=8))
    emit("kernels/kv_pack_interp_us", timeit(p1, cache),
         f"1_launch_replaces_{2*L}_slice_copies")
