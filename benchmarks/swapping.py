"""Paper Fig. 13 + Appendix E (Figs. 28–31): microbatch swapping benefit.

Throughput with the largest feasible all-resident batch B vs swapping with
2·B (two device slots + host pool).  Swapping wins while the per-step swap
transfer stays below the token step time (App. E inequality); larger
sequences/batches flip the inequality — both regimes are reported.
"""
from __future__ import annotations

from benchmarks.common import emit, emit_metric
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import MachineSpec
from repro.core.schedule import Job
from repro.core.simulator import lmsys_like_tokens, simulate_baseline


def _largest_feasible_mb(cfg, d, mach, prompt, new):
    for b in (64, 48, 32, 24, 16, 12, 8, 6, 4, 2, 1):
        wl = cm.WorkloadSpec(prompt, new, b)
        c0 = cm.layer_prompt_kv_bytes(cfg, wl)
        k0 = cm.layer_token_kv_bytes(cfg, wl)
        w0 = cm.layer_param_bytes(cfg)
        lps = -(-cfg.num_layers // d)
        # all-resident: stage holds lps layers' weights + d microbatches' KV
        need = lps * w0 + cfg.num_layers * (c0 + k0)
        if need <= mach.mem_bytes:
            return b
    return 0


def _throughput(cfg, d, mach, b, prompt, new, swapping):
    wl = cm.WorkloadSpec(prompt, new, b)
    toks = lmsys_like_tokens(24, seed=0, mean_target=new)
    jobs = [Job(i, 0.0, int(t)) for i, t in enumerate(toks)]
    r = simulate_baseline(cfg, wl, d, jobs, mach, swapping=swapping)
    total_tokens = b * sum(j.n_tokens for j in jobs)
    return total_tokens / r.makespan


def run() -> None:
    # --- paper-regime reproduction (A100/V100-era efficiency) ---------------
    # The paper's 1.8x swapping gain relies on slow per-token steps (their
    # Fig. 2: 50–100 ms/token on FasterTransformer-era GPUs), which leave a
    # wide (D−1)·t prefetch window.  We reproduce the mechanism with the
    # paper's effective-bandwidth regime, then evaluate the v5e regime where
    # App. E's inequality flips (hardware-adaptation finding, DESIGN.md §8).
    from repro.core.dejavulib.transport import HardwareModel
    paper_hw = HardwareModel(peak_flops=312e12, hbm_bw=2.0e12,
                             host_link_bw=25e9)
    paper_mach = MachineSpec(chips=2, mem_bytes=160e9)   # 2×A100-80GB VM
    # The mechanism wins where App. E's inequality holds: short contexts
    # (paper Fig. 28 shows the crossover between seq 512 and 1024) and
    # FT/V100-era effective bandwidth (per-token ~100 ms, paper Fig. 2).
    for name, d, plen, gen in (("opt-66b", 4, 128, 128),
                               ("bloom-176b", 6, 128, 128),
                               ("opt-66b", 4, 1000, 220)):   # beyond-crossover
        cfg = PAPER_ARCHS[name]
        for b in (8,):
            wl = cm.WorkloadSpec(plen, gen, b)
            toks = lmsys_like_tokens(24, seed=0, mean_target=gen)
            jobs = [Job(i, 0.0, int(t)) for i, t in enumerate(toks)]
            r0 = simulate_baseline(cfg, wl, d, jobs, paper_mach, paper_hw,
                                   beff=0.05, swapping=False)
            wl2 = cm.WorkloadSpec(plen, gen, 2 * b)
            r2 = simulate_baseline(cfg, wl2, d, jobs, paper_mach, paper_hw,
                                   beff=0.05, swapping=True)
            tp0 = b * sum(j.n_tokens for j in jobs) / r0.makespan
            tp2 = 2 * b * sum(j.n_tokens for j in jobs) / r2.makespan
            gain = tp2 / tp0
            emit_metric(f"swap_gain_{name}_D{d}_ctx{plen+gen}", gain,
                        f"(paper: up to 1.8x at short ctx, <1x beyond the "
                        f"Fig.-28 crossover)")
            # headline invariant (App. E inequality): swapping wins at
            # short contexts, loses beyond the Fig.-28 crossover
            if plen + gen <= 512:
                assert gain > 1.0, (
                    f"{name} ctx{plen+gen}: swapping gain {gain:.2f}x <= 1x "
                    f"in the paper's short-context regime")
            else:
                assert gain < 1.0, (
                    f"{name} ctx{plen+gen}: swapping gain {gain:.2f}x >= 1x "
                    f"beyond the crossover")

    # --- v5e regime: where does App. E's inequality hold? -------------------
    mach = MachineSpec()
    cfg = PAPER_ARCHS["opt-66b"]
    for seq in (256, 512, 1024, 2048, 4096):
        wl = cm.WorkloadSpec(seq // 2, seq // 2, 16)
        lps = -(-cfg.num_layers // 4)
        t = cm.stage_token_time(cfg, wl, lps, mach.chips, seq)
        tr = cm.swap_transfer_time(cfg, wl, lps, seq)
        window = 3 * t     # (D−1)·t prefetch window, D=4
        emit_metric(f"appE_swap_vs_window_seq{seq}", tr / window,
                    f"transfer={tr*1e3:.2f}ms window={(window)*1e3:.2f}ms "
                    f"{'hidden' if tr <= window else 'EXPOSED'} "
                    f"(v5e hostlink/HBM ratio makes swapping pay only below "
                    f"{int(window * 16e9 / (cfg.kv_bytes_per_token() * 16 / 4))} ctx tokens)")
        # v5e regime check (the hardware-adaptation finding): the high
        # HBM-bandwidth/host-link ratio EXPOSES the swap transfer at every
        # measured sequence length — App. E's inequality is flipped on v5e
        assert tr > window, f"seq{seq}: swap unexpectedly hidden on v5e"


if __name__ == "__main__":
    run()
