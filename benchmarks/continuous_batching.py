"""Static vs continuous batching under the paged KV pool (tentpole study).

Two views of the same question — how much throughput and memory does the
FasterTransformer-style static schedule leave on the table under a
mixed-length request trace?

1. *Modeled* (opt-66b scale): an analytic round model on the bandwidth-bound
   decode cost (`costmodel`).  Static reserves ``prompt+max_new`` per request
   for a microbatch's whole lifetime and holds every request until the
   longest peer in its group drains; continuous batching reserves live
   blocks only, retires each request at its own length, and admits queued
   work into the freed blocks every round.  Same HBM budget on both sides.

2. *Measured* (reduced gpt2, real engine): `ServingEngine.run` vs
   `ServingEngine.run_continuous` on the same trace — peak KV bytes from the
   cluster's live-byte tracker and executed steps from the report.

Emitted derived values include the modeled throughput ratio (paper-style
claim: >= 1.3x on an lmsys-like trace) and the peak-KV-bytes ratio (< 1).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from benchmarks.common import emit, emit_metric
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.dejavulib.transport import DEFAULT_HW
from repro.core.planner import MachineSpec
from repro.core.simulator import lmsys_like_tokens
from repro.kvcache.paged import blocks_for


def _trace(n: int, seed: int = 0):
    """Mixed-length trace: bucketed prompt lengths + long-tailed gen lengths."""
    rng = np.random.default_rng(seed)
    plens = rng.choice([200, 500, 1000, 1500], size=n, p=[0.3, 0.3, 0.25, 0.15])
    gens = lmsys_like_tokens(n, seed=seed, mean_target=150, max_tokens=512)
    return list(zip(plens.tolist(), gens.tolist()))


def _round_time(cfg, live_ctxs: List[int], mach: MachineSpec) -> float:
    """One decode round: weights + every live sequence's KV cross HBM."""
    w_bytes = cm.layer_param_bytes(cfg) * cfg.num_layers
    kv_bytes = sum(cfg.decode_state_bytes(c) for c in live_ctxs)
    return (w_bytes + kv_bytes) / (mach.chips * DEFAULT_HW.hbm_bw * 0.7)


def modeled_study(n_requests: int = 96, microbatch: int = 16,
                  mem_budget: float = 128e9):
    """Defaults follow the paper's serving regime (microbatch 16); the
    continuous side wins ~1.8x there — larger static groups only widen the
    gap (the group drains at its slowest member)."""
    cfg = PAPER_ARCHS["opt-66b"]
    mach = MachineSpec()
    trace = _trace(n_requests)
    bs = cfg.kv_block_size

    # --- static: length-homogeneous groups, padded reservation, group drain
    # (bucket strictly by prompt length, like serving.request.form_microbatches
    # — a chunk must never straddle two length buckets)
    buckets: dict = {}
    for p, gen in sorted(trace):
        buckets.setdefault(p, []).append((p, gen))
    groups = [b[i:i + microbatch] for b in buckets.values()
              for i in range(0, len(b), microbatch)]
    block_bytes = cfg.decode_state_bytes(bs)
    time_s = peak_s = peak_paged = 0.0
    tokens_done = 0
    max_conc = 0
    live: List[List] = []                             # [plen, gen, max_new, done]
    queue = list(groups)
    while queue or live:
        while queue:
            g = queue[0]
            need = sum(cfg.decode_state_bytes(p + max(x[1] for x in g))
                       for p, _ in g)
            used = sum(x[2] for x in live)
            if used + need > mem_budget:
                break
            g = queue.pop(0)
            n_new = max(x[1] for x in g)
            reserve = cfg.decode_state_bytes(g[0][0] + n_new)
            live += [[p, gen, reserve, 0, n_new] for p, gen in g]
        peak_s = max(peak_s, sum(x[2] for x in live))
        # counterfactual: the SAME schedule allocating live blocks instead of
        # the padded prompt+max_new reservation — the overprovisioning gap
        peak_paged = max(peak_paged, sum(
            blocks_for(p + min(d, gen), bs) * block_bytes
            for p, gen, _, d, _ in live))
        max_conc = max(max_conc, len(live))
        time_s += _round_time(cfg, [p + d for p, _, _, d, _ in live], mach)
        for x in live:
            x[3] += 1
            if x[3] <= x[1]:
                tokens_done += 1                      # useful token
        live = [x for x in live if x[3] < x[4]]       # slot frees at GROUP max
    tp_static = tokens_done / time_s

    # --- continuous: block-level reservation, per-request retire + admit;
    # concurrency capped at the static schedule's max so the memory numbers
    # compare the SAME load — the paged side still wins on both axes
    time_c = peak_c = 0.0
    tokens_done_c = 0
    live = []                                         # [plen, gen, done]
    queue_c = sorted(trace)
    while queue_c or live:
        while queue_c and len(live) < max_conc:
            p, gen = queue_c[0]
            used = sum(blocks_for(pp + d + 1, bs) * block_bytes
                       for pp, _, d in live)
            if used + blocks_for(p + 1, bs) * block_bytes > mem_budget:
                break
            queue_c.pop(0)
            live.append([p, gen, 0])
        peak_c = max(peak_c, sum(blocks_for(p + d, bs) * block_bytes
                                 for p, _, d in live))
        time_c += _round_time(cfg, [p + d for p, _, d in live], mach)
        tokens_done_c += len(live)                    # every step is useful
        for x in live:
            x[2] += 1
        live = [x for x in live if x[2] < x[1]]       # retire at OWN length
    tp_cont = tokens_done_c / time_c

    emit("cb_modeled_static_tok_s", 0.0, f"{tp_static:.1f}")
    emit("cb_modeled_continuous_tok_s", 0.0, f"{tp_cont:.1f}")
    emit_metric("cb_modeled_throughput_ratio", tp_cont / tp_static,
                "continuous vs static, same HBM budget (gate >= 1.3x)")
    emit("cb_modeled_peak_kv_gb_static_padded", 0.0, f"{peak_s / 1e9:.1f}")
    emit("cb_modeled_peak_kv_gb_paged_same_schedule", 0.0,
         f"{peak_paged / 1e9:.1f}")
    emit_metric("cb_modeled_peak_kv_ratio", peak_paged / peak_s,
                "paged live blocks vs padded reservation, same schedule (< 1)")
    emit("cb_modeled_peak_kv_gb_continuous_at_budget", 0.0,
         f"{peak_c / 1e9:.1f}")
    return tp_cont / tp_static, peak_paged / peak_s


def measured_study():
    import jax
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                              dtype="float32", num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    plens = [8, 16, 8, 16, 8, 8, 16, 8]
    gens = [12, 4, 3, 9, 5, 3, 4, 7]
    prompts = [rng.integers(0, cfg.vocab_size, (p,)).astype(np.int32)
               for p in plens]

    def mkreqs():
        return [Request(rid=i, prompt=prompts[i].copy(), max_new=gens[i])
                for i in range(len(plens))]

    static = ServingEngine(cfg, model, params, 2, microbatch=4)
    rs = static.run(mkreqs())
    cont = ServingEngine(cfg, model, params, 2, microbatch=4, paged=True,
                         kv_pool_blocks=256)
    rc = cont.run_continuous(mkreqs(), max_active=4)
    useful = sum(gens)
    emit("cb_measured_static_steps", 0.0,
         f"{rs.steps_executed} steps for {useful} useful tokens")
    emit("cb_measured_continuous_steps", 0.0, f"{rc.steps_executed}")
    emit("cb_measured_peak_kv_bytes_static", 0.0, str(rs.peak_kv_bytes))
    emit("cb_measured_peak_kv_bytes_paged", 0.0, str(rc.peak_kv_bytes))
    assert rc.peak_kv_bytes < rs.peak_kv_bytes
    for i in range(len(plens)):
        assert rs.tokens[i][:gens[i]] == rc.tokens[i]


def fused_rounds_study():
    """Fused batched rounds vs the per-sequence oracle path.

    Modeled (opt-66b scale): one decode round at N live sequences costs N
    bandwidth-bound passes per-seq (stage weights re-read every pass, one
    dispatch latency each) vs ONE fused pass (weights read once + every
    sequence's KV) — `cm.decode_round_time` on both sides.  Gate: >= 2x at
    8 active sequences.

    Measured (reduced gpt2 + reduced bloom, real engine): same trace through
    `run_continuous` with `fused_rounds` on (the default) vs off —
    token-identical outputs, and `EngineReport.pass_trace` shows O(1) passes
    per decode round in the active count (1 fused pass where the oracle path
    runs one per sequence).  bloom exercises the ALiBi batched-bias path
    that used to be excluded from the fused gate.
    """
    ratios8 = {}
    for arch in ("opt-66b", "bloom-176b"):
        cfg = PAPER_ARCHS[arch]
        ctx = 1500
        tag = arch.split("-")[0]
        for n in (1, 2, 4, 8, 16):
            per = cm.decode_round_time(cfg, n, ctx, cfg.num_layers, 8,
                                       fused=False)
            fus = cm.decode_round_time(cfg, n, ctx, cfg.num_layers, 8,
                                       fused=True)
            emit(f"fused_modeled_round_ms_perseq_{tag}_n{n}", 0.0,
                 f"{per * 1e3:.2f}")
            emit(f"fused_modeled_round_ms_fused_{tag}_n{n}", 0.0,
                 f"{fus * 1e3:.2f}")
            if n == 8:
                emit_metric(f"fused_modeled_round_speedup_{tag}_n{n}",
                            per / fus, "one fused pass vs N per-seq passes "
                            "(gate >= 2x)")
                ratios8[arch] = per / fus
            else:
                emit(f"fused_modeled_round_speedup_{tag}_n{n}", 0.0,
                     f"{per / fus:.2f}x")

    # --- measured: 8 sequences decoding together, passes per round --------
    import jax
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    for arch, layers, nseq in (("gpt2-1.5b", 4, 8), ("bloom-176b", 2, 6)):
        rcfg = dataclasses.replace(PAPER_ARCHS[arch].reduced(),
                                   dtype="float32", num_layers=layers)
        model = build_model(rcfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, rcfg.vocab_size, (8,)).astype(np.int32)
                   for _ in range(nseq)]

        def mkreqs():
            return [Request(rid=i, prompt=prompts[i].copy(), max_new=6)
                    for i in range(nseq)]

        tag = arch.split("-")[0]
        kw = dict(paged=True, kv_pool_blocks=256)
        rb = ServingEngine(rcfg, model, params, 2, fused_rounds=False,
                           **kw).run_continuous(mkreqs(), max_active=nseq)
        rf = ServingEngine(rcfg, model, params, 2, **kw).run_continuous(
            mkreqs(), max_active=nseq)
        assert rf.tokens == rb.tokens, \
            f"fused rounds changed the tokens ({arch})"
        # steady rounds (no admissions, no in-flight prefills, full batch):
        # the oracle path runs one pass per sequence, the fused path ONE
        steady = [p for b, p in zip(rf.batch_trace[1:], rf.pass_trace[1:])
                  if b == nseq]
        steady_base = [p for b, p
                       in zip(rb.batch_trace[1:], rb.pass_trace[1:])
                       if b == nseq]
        assert steady and all(p == 1 for p in steady), \
            f"fused {nseq}-active rounds must be ONE pass: {rf.pass_trace}"
        assert all(p == nseq for p in steady_base), rb.pass_trace
        emit(f"fused_measured_passes_{nseq}active_perseq_{tag}", 0.0,
             str(steady_base[0]))
        emit(f"fused_measured_passes_{nseq}active_fused_{tag}", 0.0,
             str(steady[0]))
        emit(f"fused_measured_total_passes_{tag}", 0.0,
             f"{sum(rf.pass_trace)} vs {sum(rb.pass_trace)} per-seq")
    return ratios8


def run() -> None:
    ratio, mem_ratio = modeled_study()
    assert ratio >= 1.3, f"continuous batching modeled speedup {ratio:.2f} < 1.3"
    assert mem_ratio < 1.0
    measured_study()
    ratios8 = fused_rounds_study()
    for arch, r in ratios8.items():
        assert r >= 2.0, \
            f"fused round latency speedup {r:.2f}x < 2x at 8 active ({arch})"


if __name__ == "__main__":
    run()
