"""Roofline table from the compiled dry-run artifacts (assignment §Roofline).

Reads results/dryrun_*.json (produced by `python -m repro.launch.dryrun`) and
prints per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and per-device HBM residency.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

DEFAULT_PATH = "results/dryrun_baseline.json"


def load(path: str = DEFAULT_PATH):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def run(path: str = DEFAULT_PATH) -> None:
    rows = load(path)
    if not rows:
        emit("roofline/missing", 0.0, f"run `python -m repro.launch.dryrun` first")
        return
    n_ok = n_skip = n_err = 0
    for r in rows:
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skipped":
            n_skip += 1
            emit(tag, 0.0, "skipped_subquadratic_rule")
            continue
        if r["status"] != "ok":
            n_err += 1
            emit(tag, 0.0, f"ERROR:{r.get('error','')[:60]}")
            continue
        n_ok += 1
        rf = r["roofline"]
        dom_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        emit(tag, dom_s * 1e6,
             f"dom={rf['dominant']} compute={rf['compute_s']*1e3:.2f}ms "
             f"mem={rf['memory_s']*1e3:.2f}ms coll={rf['collective_s']*1e3:.2f}ms "
             f"useful={rf['useful_flops_ratio']*100:.1f}% "
             f"hbm/dev={r['per_device']['hbm_total_bytes']/1e9:.1f}GB "
             f"fits={r['fits_hbm']}")
    emit("roofline/summary", float(n_ok) * 1e6,
         f"ok={n_ok} skipped={n_skip} errors={n_err}")


if __name__ == "__main__":
    run()
