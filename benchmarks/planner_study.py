"""Paper Appendix B (Figs. 20–23, Tables 2–5): planner study.

For D available machines, find the best configuration per policy —
Baseline (TP+PP), Baseline-DP (d pipelines × depth D/d), DéjàVu (Dp + Dt) —
over microbatch sizes, and report makespan + normalized cost on an LMSys-like
trace (prompt 1000).  Mirrors the paper's tables: best config per cell.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import MachineSpec, plan
from repro.core.schedule import Job
from repro.core.simulator import (lmsys_like_tokens, simulate_baseline,
                                  simulate_dejavu, simulate_dp)

N_REQ = 256          # requests in the trace
MEAN_TOK = 150


def _jobs(mb: int, seed=0):
    n = max(N_REQ // mb, 4)
    toks = lmsys_like_tokens(n, seed=seed, mean_target=MEAN_TOK)
    return [Job(i, 0.0, int(toks[i])) for i in range(n)]


def study(cfg, machines=(2, 4, 8, 12, 16), batches=(4, 8, 16, 32)):
    mach = MachineSpec()
    for d in machines:
        best = {}
        for b in batches:
            wl = cm.WorkloadSpec(1000, MEAN_TOK, b)
            jobs = _jobs(b)
            # Baseline
            try:
                r = simulate_baseline(cfg, wl, d, jobs, mach)
                if np.isfinite(r.makespan):
                    cur = best.get("baseline")
                    if cur is None or r.makespan < cur[0]:
                        best["baseline"] = (r.makespan, f"({d}p,{b}b)")
            except Exception:
                pass
            # Baseline-DP
            for nd in (2, 4):
                if d % nd == 0 and d // nd >= 1:
                    r = simulate_dp(cfg, wl, d, nd, jobs, mach)
                    cur = best.get("baseline-dp")
                    if cur is None or r.makespan < cur[0]:
                        best["baseline-dp"] = (r.makespan, f"({nd}d,{d//nd}p,{b}b)")
            # DejaVu (planner split)
            p = plan(cfg, wl, d, mach)
            if p.feasible:
                r = simulate_dejavu(cfg, wl, d, jobs, mach, the_plan=p)
                cur = best.get("dejavu")
                if cur is None or r.makespan < cur[0]:
                    best["dejavu"] = (r.makespan,
                                      f"(({p.d_prompt}p,{b}b),({p.d_token}p,{b}b))")
        for policy, (mk, conf) in sorted(best.items()):
            cost = mk / 3600.0 * d
            emit(f"appB/{cfg.name}/D{d}/{policy}/makespan_s", mk * 1e6,
                 f"best={conf} norm_cost={cost:.3f}mach·h")
        if "baseline" in best and "dejavu" in best:
            emit(f"appB/{cfg.name}/D{d}/dejavu_vs_baseline",
                 best["baseline"][0] / best["dejavu"][0] * 1e6,
                 f"{best['baseline'][0]/best['dejavu'][0]:.2f}x "
                 f"(paper mean 4.2x on V100-16GB fleets)")


def run() -> None:
    study(PAPER_ARCHS["opt-66b"], machines=(4, 8, 12, 16))
    study(PAPER_ARCHS["bloom-176b"], machines=(8, 12, 16))
