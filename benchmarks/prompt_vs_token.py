"""Paper Fig. 2 + Appendix A (Figs. 16–19): bimodal prompt vs token latency.

Prompt processing is compute-bound and scales with batch·prompt_len; per-token
generation is bandwidth-bound and nearly constant — the ratio (up to ~106× in
the paper) is the pipeline-bubble driver that motivates disaggregation.
Derived from the calibrated v5e cost model on the paper's models + assigned
archs.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import ARCHS
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import MachineSpec


def run() -> None:
    mach = MachineSpec()
    rows = []
    for name in ("opt-66b", "bloom-176b", "gpt2-1.5b"):
        cfg = PAPER_ARCHS[name]
        for b in (1, 8, 32):
            for plen in (250, 1000, 4000):
                wl = cm.WorkloadSpec(plen, 1, b)
                y = cm.stage_prompt_time(cfg, wl, cfg.num_layers, 8 * mach.chips)
                t = cm.stage_token_time(cfg, wl, cfg.num_layers, 8 * mach.chips,
                                        plen + 500)
                emit(f"fig2/{name}/b{b}/p{plen}/prompt_ms", y * 1e9 / 1e3,
                     f"ratio={y/t:.1f}x")
                emit(f"fig2/{name}/b{b}/p{plen}/token_ms", t * 1e9 / 1e3, "")
                rows.append(y / t)
    for name in sorted(ARCHS):
        cfg = ARCHS[name]
        wl = cm.WorkloadSpec(1000, 1, 8)
        y = cm.stage_prompt_time(cfg, wl, cfg.num_layers, 8 * mach.chips)
        t = cm.stage_token_time(cfg, wl, cfg.num_layers, 8 * mach.chips, 1500)
        emit(f"fig2/{name}/b8/p1000/ratio", y / t * 1e6, f"{y/t:.1f}x")
    emit("fig2/max_ratio", max(rows) * 1e6, f"paper_reports_up_to_106x")
