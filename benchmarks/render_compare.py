"""Render the baseline → optimized comparison table for EXPERIMENTS.md."""
from __future__ import annotations

import json
import sys


def key(r):
    return (r["arch"], r["shape"], r["mesh"])


def main(base_path="results/dryrun_baseline.json",
         opt_path="results/dryrun_opt.json"):
    base = {key(r): r for r in json.load(open(base_path))}
    opt = {key(r): r for r in json.load(open(opt_path))}
    rows = ["| arch | shape | mesh | dominant (base → opt) | base dom (ms) | "
            "opt dom (ms) | speedup | useful FLOPs (base → opt) | HBM GB (base → opt) |",
            "|---|---|---|---|---|---|---|---|---|"]
    speedups = []
    for k in sorted(base):
        b, o = base[k], opt.get(k)
        if b["status"] != "ok" or o is None or o["status"] != "ok":
            continue
        rb, ro = b["roofline"], o["roofline"]
        db = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
        do = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        sp = db / do if do else float("nan")
        speedups.append((sp, k))
        rows.append(
            f"| {k[0]} | {k[1]} | {k[2]} | {rb['dominant']} → {ro['dominant']} "
            f"| {db*1e3:.2f} | {do*1e3:.2f} | **{sp:.2f}×** "
            f"| {rb['useful_flops_ratio']*100:.1f}% → {ro['useful_flops_ratio']*100:.1f}% "
            f"| {b['per_device']['hbm_total_bytes']/1e9:.1f} → "
            f"{o['per_device']['hbm_total_bytes']/1e9:.1f} |")
    print("\n".join(rows))
    if speedups:
        import statistics
        sps = [s for s, _ in speedups]
        print(f"\ngeomean speedup on the dominant roofline term: "
              f"**{statistics.geometric_mean(sps):.2f}×** over {len(sps)} cells "
              f"(max {max(sps):.1f}×, min {min(sps):.2f}×)")


if __name__ == "__main__":
    main(*sys.argv[1:])
