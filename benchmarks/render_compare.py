"""Render the baseline → optimized comparison table for EXPERIMENTS.md.

With two DIRECTORY arguments, compares the SLO percentiles of matching
``repro.bench/v1`` artifacts — read from each artifact's embedded
``repro.telemetry/v1`` snapshot (the CI-gated numbers), never recomputed
from raw trace lists.
"""
from __future__ import annotations

import json
import os
import sys


def key(r):
    return (r["arch"], r["shape"], r["mesh"])


def main(base_path="results/dryrun_baseline.json",
         opt_path="results/dryrun_opt.json"):
    base = {key(r): r for r in json.load(open(base_path))}
    opt = {key(r): r for r in json.load(open(opt_path))}
    rows = ["| arch | shape | mesh | dominant (base → opt) | base dom (ms) | "
            "opt dom (ms) | speedup | useful FLOPs (base → opt) | HBM GB (base → opt) |",
            "|---|---|---|---|---|---|---|---|---|"]
    speedups = []
    for k in sorted(base):
        b, o = base[k], opt.get(k)
        if b["status"] != "ok" or o is None or o["status"] != "ok":
            continue
        rb, ro = b["roofline"], o["roofline"]
        db = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
        do = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        sp = db / do if do else float("nan")
        speedups.append((sp, k))
        rows.append(
            f"| {k[0]} | {k[1]} | {k[2]} | {rb['dominant']} → {ro['dominant']} "
            f"| {db*1e3:.2f} | {do*1e3:.2f} | **{sp:.2f}×** "
            f"| {rb['useful_flops_ratio']*100:.1f}% → {ro['useful_flops_ratio']*100:.1f}% "
            f"| {b['per_device']['hbm_total_bytes']/1e9:.1f} → "
            f"{o['per_device']['hbm_total_bytes']/1e9:.1f} |")
    print("\n".join(rows))
    if speedups:
        import statistics
        sps = [s for s, _ in speedups]
        print(f"\ngeomean speedup on the dominant roofline term: "
              f"**{statistics.geometric_mean(sps):.2f}×** over {len(sps)} cells "
              f"(max {max(sps):.1f}×, min {min(sps):.2f}×)")


def _bench_histograms(path):
    """{artifact_stem: {metric: hist}} for every repro.bench/v1 file."""
    out = {}
    for fn in sorted(os.listdir(path)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(path, fn)) as f:
            try:
                doc = json.load(f)
            except ValueError:
                continue
        if not isinstance(doc, dict) or doc.get("schema") != "repro.bench/v1":
            continue
        tele = doc.get("telemetry") or {}
        if tele.get("schema") == "repro.telemetry/v1":
            out[fn[:-5]] = tele.get("histograms", {})
    return out


def compare_bench_dirs(base_dir, new_dir):
    base, new = _bench_histograms(base_dir), _bench_histograms(new_dir)
    rows = ["| artifact | metric | p50 (base → new) | p99 (base → new) | Δp99 |",
            "|---|---|---|---|---|"]
    for stem in sorted(set(base) & set(new)):
        for key in sorted(set(base[stem]) & set(new[stem])):
            hb, hn = base[stem][key], new[stem][key]
            d = (hn["p99_s"] / hb["p99_s"] - 1.0) if hb["p99_s"] else 0.0
            rows.append(
                f"| {stem} | {key} | {hb['p50_s']:.3e} → {hn['p50_s']:.3e} "
                f"| {hb['p99_s']:.3e} → {hn['p99_s']:.3e} | {d:+.1%} |")
    print("\n".join(rows))


if __name__ == "__main__":
    if (len(sys.argv) == 3 and os.path.isdir(sys.argv[1])
            and os.path.isdir(sys.argv[2])):
        compare_bench_dirs(sys.argv[1], sys.argv[2])
    else:
        main(*sys.argv[1:])
