"""Paper Fig. 11 + Appendix D (Fig. 27): DéjàVuLib streaming optimizations.

Single-batch latency slowdown when streaming the KV cache to remote CPU
memory, gradually applying: (0) naive per-slice copies, (1) buffered copies
(kv_pack), (2) + layer-by-layer prompt overlap, (3) + token-compute overlap.
Real arrays move through the primitives at reduced scale (wall time), while
the modeled timeline is evaluated at the paper's scale (OPT-66B, prompt 500,
500 new tokens).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.dejavulib import HostMemoryStore, NetworkTransport, scatter
from repro.core.dejavulib.transport import DEFAULT_HW
from repro.core.planner import MachineSpec


def _modeled(cfg, prompt=500, new=500, mb=8):
    """Modeled per-request streaming seconds under each optimization level."""
    hw = DEFAULT_HW
    mach = MachineSpec()
    wl = cm.WorkloadSpec(prompt, new, mb)
    kv_tok = cfg.kv_bytes_per_token() * mb               # bytes per step
    kv_prompt = cfg.decode_state_bytes(prompt) * mb
    t_tok = cm.stage_token_time(cfg, wl, cfg.num_layers, 8 * mach.chips,
                                prompt + new)
    y = cm.stage_prompt_time(cfg, wl, cfg.num_layers, 8 * mach.chips)
    # level 0: per (layer, k/v) slice transfers each step: 2L messages
    n_msgs = 2 * cfg.num_layers
    lvl0 = new * (n_msgs * hw.net_latency + kv_tok / hw.dcn_stream_bw) \
        + (n_msgs * hw.net_latency + kv_prompt / hw.dcn_stream_bw)
    # level 1: buffered copies -> 1 message per step
    lvl1 = new * (hw.net_latency + kv_tok / hw.dcn_stream_bw) \
        + (hw.net_latency + kv_prompt / hw.dcn_stream_bw)
    # level 2: + layer-by-layer prompt streaming overlap (prompt part hidden
    # behind prompt compute, residual 10%)
    prompt_part = hw.net_latency + kv_prompt / hw.dcn_stream_bw
    lvl2 = (lvl1 - prompt_part) + max(0.0, prompt_part - y) + 0.1 * min(prompt_part, y)
    # level 3: + token streaming hidden behind next-step compute
    tok_part = hw.net_latency + kv_tok / hw.dcn_stream_bw
    exposed_tok = max(0.0, tok_part - t_tok)
    lvl3 = (lvl2 - new * tok_part) + new * exposed_tok
    base_exec = y + new * t_tok
    return [(f"lvl{i}", v, (base_exec + v) / base_exec)
            for i, v in enumerate((lvl0, lvl1, lvl2, lvl3))]


def run() -> None:
    cfg = PAPER_ARCHS["opt-66b"]
    levels = _modeled(cfg)
    for name, stream_s, slowdown in levels:
        emit(f"fig11/opt-66b/{name}/stream_s", stream_s * 1e6,
             f"serving_slowdown={slowdown:.3f}x")
    emit("fig11/buffered_copies_gain",
         levels[0][1] / levels[1][1] * 1e6,
         f"{levels[0][1]/levels[1][1]:.0f}x_fewer_transfer_overheads")
    emit("fig11/final_slowdown_pct", (levels[3][2] - 1) * 100 * 1e6,
         "paper_reports_within_2pct")

    # real bytes through the primitives (reduced scale, wall-time)
    l, b, s, h, d = 16, 2, 64, 4, 16
    cache = jax.numpy.asarray(np.random.randn(l, b, s, h, d).astype(np.float32))
    tr = NetworkTransport()
    import time
    t0 = time.perf_counter()
    scatter(cache, "kv/k", (32, 33), HostMemoryStore(), tr, buffered=False)
    wall_base = time.perf_counter() - t0
    m_base = tr.modeled_total(); tr.reset_log()
    t0 = time.perf_counter()
    scatter(cache, "kv/k", (32, 33), HostMemoryStore(), tr, buffered=True)
    wall_buf = time.perf_counter() - t0
    m_buf = tr.modeled_total()
    emit("fig11/real/baseline_us", wall_base * 1e6, f"modeled={m_base*1e6:.1f}us")
    emit("fig11/real/buffered_us", wall_buf * 1e6,
         f"modeled={m_buf*1e6:.1f}us modeled_gain={m_base/m_buf:.1f}x")

    # hot-path integrity-gate micro-benchmark: the O(nbytes) byte-compare
    # standing in for a checksum (Transport._realize_loss) runs ONLY with a
    # FaultInjector installed — normal streaming pays one copy + bookkeeping.
    # Three regimes on a 4 MiB payload: no injector (fast path), injector
    # installed but no matching fault (one counter bump), and always-corrupt
    # (bit-flip + full compare + retransmit copy).  Wall times are
    # informational, not trend-gated.
    from repro.core.dejavulib import faults
    payload = np.zeros(4 << 20, np.uint8)
    tr.reset_log()
    t_fast = timeit(lambda: tr.transfer(payload, tag="microbench"),
                    iters=20, warmup=3)
    idle = faults.FaultInjector()
    with faults.active(idle):
        t_idle = timeit(lambda: tr.transfer(payload, tag="microbench"),
                        iters=20, warmup=3)
    lossy = faults.FaultInjector(faults.FaultPlan([faults.FaultSpec(
        "transport.transfer.net", nth=1, kind="corrupt", times=1 << 30)]))
    with faults.active(lossy):
        t_corrupt = timeit(lambda: tr.transfer(payload, tag="microbench"),
                           iters=20, warmup=3)
    emit("fig11/transfer_fastpath_us", t_fast,
         "no injector: copy + bookkeeping, no byte-compare")
    emit("fig11/transfer_injector_idle_us", t_idle,
         f"injector installed, no matching fault ({t_idle/t_fast:.2f}x fast)")
    emit("fig11/transfer_always_corrupt_us", t_corrupt,
         f"integrity check + retransmit ({t_corrupt/t_fast:.2f}x fast path)")

    # flight-recorder gate micro-benchmark: with no tracer installed the
    # transfer hot path pays exactly one `tracing.active()` is-None check;
    # with a Tracer installed each transfer also appends one ring-buffer
    # event.  Same shape as the injector gate above: informational wall
    # times, not trend-gated.
    from repro.core import tracing
    t_trace_off = timeit(lambda: tr.transfer(payload, tag="microbench"),
                         iters=20, warmup=3)
    prev = tracing.install(tracing.Tracer())
    try:
        t_trace_on = timeit(lambda: tr.transfer(payload, tag="microbench"),
                            iters=20, warmup=3)
    finally:
        tracing.uninstall(prev)
    emit("fig11/transfer_tracing_off_us", t_trace_off,
         "no tracer: hot path is a single is-None check")
    emit("fig11/transfer_tracing_on_us", t_trace_on,
         f"tracer installed: +1 ring append "
         f"({t_trace_on/t_trace_off:.2f}x tracing-off)")


if __name__ == "__main__":
    run()
