"""Paper Fig. 12: median normalized latency (s/token) vs request rate —
colocated FasterTransformer-style baseline vs DéjàVu disaggregation, for
OPT-66B (8 machines) and BLOOM-176B (12 machines), LMSys-like output lengths,
Poisson arrivals, prompt 1000.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import MachineSpec, plan
from repro.core.schedule import Job
from repro.core.simulator import (lmsys_like_tokens, poisson_arrivals,
                                  simulate_baseline, simulate_dejavu)


def _sweep(cfg, d, rates, n_jobs=48, mean_tok=150):
    mach = MachineSpec()
    wl = cm.WorkloadSpec(1000, mean_tok, 16)
    toks = lmsys_like_tokens(n_jobs, seed=0, mean_target=mean_tok)
    p = plan(cfg, wl, d, mach)
    max_sustain = {"baseline": 0.0, "dejavu": 0.0}
    for rate in rates:
        arr = poisson_arrivals(n_jobs, rate, seed=1)
        jobs = [Job(i, float(arr[i]), int(toks[i])) for i in range(n_jobs)]
        rb = simulate_baseline(cfg, wl, d, jobs, mach)
        rdv = simulate_dejavu(cfg, wl, d, jobs, mach, the_plan=p)
        emit(f"fig12/{cfg.name}/D{d}/rate{rate:g}/baseline_norm_lat",
             rb.normalized_latency * 1e6, f"makespan={rb.makespan:.0f}s")
        emit(f"fig12/{cfg.name}/D{d}/rate{rate:g}/dejavu_{p.d_prompt}-{p.d_token}_norm_lat",
             rdv.normalized_latency * 1e6, f"makespan={rdv.makespan:.0f}s")
        # "sustained" = normalized latency below 2x the unloaded value
        if rb.normalized_latency < 2 * rdv.normalized_latency or True:
            pass
        for k, r in (("baseline", rb), ("dejavu", rdv)):
            if np.isfinite(r.normalized_latency):
                max_sustain[k] = max(max_sustain[k], rate) if \
                    r.normalized_latency < 0.35 else max_sustain[k]
    gain = (max_sustain["dejavu"] / max_sustain["baseline"]
            if max_sustain["baseline"] else float("nan"))
    emit(f"fig12/{cfg.name}/sustained_rate_gain", gain * 1e6,
         f"dejavu={max_sustain['dejavu']:g}rps baseline={max_sustain['baseline']:g}rps "
         f"(paper: 1.88x OPT-66B, 2x BLOOM-176B)")


def run() -> None:
    _sweep(PAPER_ARCHS["opt-66b"], 8, rates=(0.2, 0.4, 0.6, 0.8, 1.0, 1.2))
    _sweep(PAPER_ARCHS["bloom-176b"], 12, rates=(0.1, 0.2, 0.3, 0.4, 0.6))
