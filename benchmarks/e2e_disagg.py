"""Paper Fig. 12: median normalized latency (s/token) vs request rate —
colocated FasterTransformer-style baseline vs DéjàVu disaggregation, for
OPT-66B (8 machines) and BLOOM-176B (12 machines), LMSys-like output lengths,
Poisson arrivals, prompt 1000.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, emit_metric
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import MachineSpec, plan
from repro.core.schedule import Job
from repro.core.simulator import (lmsys_like_tokens, poisson_arrivals,
                                  simulate_baseline, simulate_dejavu)


def _sweep(cfg, d, rates, n_jobs=48, mean_tok=150):
    mach = MachineSpec()
    wl = cm.WorkloadSpec(1000, mean_tok, 16)
    toks = lmsys_like_tokens(n_jobs, seed=0, mean_target=mean_tok)
    p = plan(cfg, wl, d, mach)
    max_sustain = {"baseline": 0.0, "dejavu": 0.0}
    sustain_thresh = None   # 1.25x the baseline's unloaded norm-lat
    for rate in rates:
        arr = poisson_arrivals(n_jobs, rate, seed=1)
        jobs = [Job(i, float(arr[i]), int(toks[i])) for i in range(n_jobs)]
        rb = simulate_baseline(cfg, wl, d, jobs, mach)
        rdv = simulate_dejavu(cfg, wl, d, jobs, mach, the_plan=p)
        emit(f"fig12/{cfg.name}/D{d}/rate{rate:g}/baseline_norm_lat",
             rb.normalized_latency * 1e6, f"makespan={rb.makespan:.0f}s")
        emit(f"fig12/{cfg.name}/D{d}/rate{rate:g}/dejavu_{p.d_prompt}-{p.d_token}_norm_lat",
             rdv.normalized_latency * 1e6, f"makespan={rdv.makespan:.0f}s")
        if np.isfinite(rb.normalized_latency) and \
                np.isfinite(rdv.normalized_latency):
            # headline invariant: disaggregation never costs normalized
            # latency at any offered rate (the paper's Fig. 12 dominance)
            assert rdv.normalized_latency <= rb.normalized_latency * 1.001, (
                f"{cfg.name} rate={rate}: dejavu norm-lat "
                f"{rdv.normalized_latency:.3f}s > baseline "
                f"{rb.normalized_latency:.3f}s")
        # "sustained" = normalized latency still within 25% of the
        # baseline's unloaded (lowest-rate) value — a model-independent
        # knee, unlike an absolute cut (BLOOM's unloaded norm-lat already
        # exceeds OPT's saturated one)
        if sustain_thresh is None:
            sustain_thresh = 1.25 * rb.normalized_latency
        for k, r in (("baseline", rb), ("dejavu", rdv)):
            if np.isfinite(r.normalized_latency):
                max_sustain[k] = max(max_sustain[k], rate) if \
                    r.normalized_latency < sustain_thresh else max_sustain[k]
    gain = (max_sustain["dejavu"] / max_sustain["baseline"]
            if max_sustain["baseline"] else float("nan"))
    emit_metric(f"e2e_sustained_rate_gain_{cfg.name}", gain,
                f"dejavu={max_sustain['dejavu']:g}rps "
                f"baseline={max_sustain['baseline']:g}rps "
                f"(paper: 1.88x OPT-66B, 2x BLOOM-176B)")
    # headline gate: disaggregation sustains a strictly higher request rate
    assert gain > 1.0, (
        f"{cfg.name}: disaggregation sustained-rate gain {gain:.2f}x <= 1x")


def run() -> None:
    _sweep(PAPER_ARCHS["opt-66b"], 8, rates=(0.2, 0.4, 0.6, 0.8, 1.0, 1.2))
    _sweep(PAPER_ARCHS["bloom-176b"], 12, rates=(0.1, 0.2, 0.3, 0.4, 0.6))


if __name__ == "__main__":
    run()
