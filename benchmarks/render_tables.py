"""Render EXPERIMENTS.md tables from dry-run JSON artifacts, and SLO
percentile tables from ``repro.bench/v1`` artifacts.

With a directory argument, every ``*.json`` in it that carries the
``repro.bench/v1`` schema is rendered as an SLO table whose p50/p90/p99
come straight from the EMBEDDED ``repro.telemetry/v1`` snapshot — the
same numbers ``tools/check_bench_trend.py`` gates on — never recomputed
from raw trace lists (which used different interpolation and could
disagree with CI).
"""
from __future__ import annotations

import json
import os
import sys


def fmt_row(r):
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip | — | — | — | — | — | — | "
                "sub-quadratic rule (DESIGN.md) |")
    if r["status"] != "ok":
        return f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | | | {r.get('error','')[:40]} |"
    rf, pd = r["roofline"], r["per_device"]
    note = {
        "compute": "more chips / better MFU",
        "memory": "cut activation+score traffic (flash/blocked attn, in-place cache)",
        "collective": "cheaper TP reduction (bf16 AR, zMLP, fewer reshards)",
    }[rf["dominant"]]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
            f"| {rf['compute_s']*1e3:.2f} | {rf['memory_s']*1e3:.2f} "
            f"| {rf['collective_s']*1e3:.2f} | **{rf['dominant']}** "
            f"| {rf['useful_flops_ratio']*100:.1f}% "
            f"| {pd['hbm_total_bytes']/1e9:.1f} {'✓' if r['fits_hbm'] else '✗'} "
            f"| {note} |")


def render(path, title):
    rows = json.load(open(path))
    out = [f"### {title}", "",
           "| arch | shape | mesh | step | compute (ms) | memory (ms) | "
           "collective (ms) | dominant | useful FLOPs | HBM/dev GB (fits) | to move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(fmt_row(r))
    return "\n".join(out)


def slo_rows(doc):
    """Percentile rows from a ``repro.bench/v1`` artifact's embedded
    telemetry snapshot (the CI-gated numbers; never recomputed)."""
    tele = doc.get("telemetry")
    if not tele or tele.get("schema") != "repro.telemetry/v1":
        return []
    out = []
    for key, h in sorted(tele.get("histograms", {}).items()):
        out.append((key, h["count"], h["p50_s"], h["p90_s"], h["p99_s"],
                    h["max_s"]))
    return out


def render_bench_dir(path):
    out = []
    for fn in sorted(os.listdir(path)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(path, fn)) as f:
            try:
                doc = json.load(f)
            except ValueError:
                continue
        if not isinstance(doc, dict) or doc.get("schema") != "repro.bench/v1":
            continue                     # trace dumps etc. live here too
        rows = slo_rows(doc)
        if not rows:
            continue
        out += [f"### {fn[:-5]} — SLO percentiles (modeled seconds)", "",
                "| metric | n | p50 | p90 | p99 | max |",
                "|---|---|---|---|---|---|"]
        for key, n, p50, p90, p99, mx in rows:
            out.append(f"| {key} | {n} | {p50:.3e} | {p90:.3e} "
                       f"| {p99:.3e} | {mx:.3e} |")
        out.append("")
    return "\n".join(out)


if __name__ == "__main__":
    if len(sys.argv) > 1 and os.path.isdir(sys.argv[1]):
        print(render_bench_dir(sys.argv[1]))
    else:
        for path, title in [("results/dryrun_baseline.json", "Baseline (paper-faithful)"),
                            ("results/dryrun_opt.json", "Optimized (beyond-paper)")]:
            if os.path.exists(path):
                print(render(path, title))
                print()
