"""Render EXPERIMENTS.md tables from dry-run JSON artifacts."""
from __future__ import annotations

import json
import os
import sys


def fmt_row(r):
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip | — | — | — | — | — | — | "
                "sub-quadratic rule (DESIGN.md) |")
    if r["status"] != "ok":
        return f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | | | {r.get('error','')[:40]} |"
    rf, pd = r["roofline"], r["per_device"]
    note = {
        "compute": "more chips / better MFU",
        "memory": "cut activation+score traffic (flash/blocked attn, in-place cache)",
        "collective": "cheaper TP reduction (bf16 AR, zMLP, fewer reshards)",
    }[rf["dominant"]]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
            f"| {rf['compute_s']*1e3:.2f} | {rf['memory_s']*1e3:.2f} "
            f"| {rf['collective_s']*1e3:.2f} | **{rf['dominant']}** "
            f"| {rf['useful_flops_ratio']*100:.1f}% "
            f"| {pd['hbm_total_bytes']/1e9:.1f} {'✓' if r['fits_hbm'] else '✗'} "
            f"| {note} |")


def render(path, title):
    rows = json.load(open(path))
    out = [f"### {title}", "",
           "| arch | shape | mesh | step | compute (ms) | memory (ms) | "
           "collective (ms) | dominant | useful FLOPs | HBM/dev GB (fits) | to move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(fmt_row(r))
    return "\n".join(out)


if __name__ == "__main__":
    for path, title in [("results/dryrun_baseline.json", "Baseline (paper-faithful)"),
                        ("results/dryrun_opt.json", "Optimized (beyond-paper)")]:
        if os.path.exists(path):
            print(render(path, title))
            print()
