"""Chunked paged prefill + chunk-interleaved scheduling vs atomic prefill.

*Measured* (reduced gpt2, real engine): a long-prompt request is admitted
next to short requests that are mid-decode.  Without chunking, the whole
prompt prefills in the admission round and every co-scheduled decode waits
it out; with `prefill_chunk_tokens` set, each round runs ONE chunk pass
next to the decodes, so the worst decode-round stall is one chunk.  The
per-round stall (modeled prefill seconds co-scheduled with >=1 decode step,
`EngineReport.prefill_stall_trace`) is summarised as p99; outputs are
asserted token-identical and the adopted-suffix pass bound
(ceil(suffix/chunk)) is gated.

*Modeled* (opt-66b scale): the costmodel's chunked-prefill terms — total
prompt time vs chunk size (the dispatch-latency price of chunking) and the
decode-stall / bubble-fraction bound the planner now reports.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from benchmarks.common import emit, emit_metric
from repro.configs.registry import PAPER_ARCHS
from repro.core import costmodel as cm
from repro.core.planner import plan

CHUNK = 16
LONG_PLEN = 96
SHORT_PLEN = 8
MAX_NEW = 10


def _p99(trace):
    return float(np.percentile(np.asarray(trace, np.float64), 99)) if trace else 0.0


def measured_study() -> None:
    import jax
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(PAPER_ARCHS["gpt2-1.5b"].reduced(),
                              dtype="float32", num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (SHORT_PLEN,)).astype(np.int32)
               for _ in range(2)]
    prompts.append(rng.integers(0, cfg.vocab_size,
                                (LONG_PLEN,)).astype(np.int32))

    def mkreqs():
        return [Request(rid=i, prompt=p.copy(), max_new=MAX_NEW)
                for i, p in enumerate(prompts)]

    base = ServingEngine(cfg, model, params, 2, paged=True,
                         kv_pool_blocks=128, prefill_chunk_tokens=0)
    rb = base.run_continuous(mkreqs(), max_active=3)
    chk = ServingEngine(cfg, model, params, 2, paged=True,
                        kv_pool_blocks=128, prefill_chunk_tokens=CHUNK)
    rc = chk.run_continuous(mkreqs(), max_active=3)
    assert rc.tokens == rb.tokens, "chunk-interleaved outputs diverged"

    p99_base, p99_chunk = _p99(rb.prefill_stall_trace), _p99(rc.prefill_stall_trace)
    emit("chunked_decode_stall_p99_us_atomic", 0.0, f"{p99_base * 1e6:.4f}")
    emit("chunked_decode_stall_p99_us_interleaved", 0.0, f"{p99_chunk * 1e6:.4f}")
    assert p99_chunk < p99_base, (
        f"interleaving did not reduce the decode-stall p99 "
        f"({p99_chunk:.2e}s vs {p99_base:.2e}s)")
    emit_metric("chunked_decode_stall_p99_ratio",
                p99_base / max(p99_chunk, 1e-30),
                "atomic vs chunk-interleaved decode-round stall p99 (> 1)")
    # the long prompt really was spread over ceil(plen/chunk) passes
    assert chk.cluster.prefill_passes[2] == math.ceil(LONG_PLEN / CHUNK)
    emit("chunked_prefill_passes_long_prompt", 0.0,
         f"{chk.cluster.prefill_passes[2]} (chunk={CHUNK}, plen={LONG_PLEN})")


def modeled_study() -> None:
    cfg = PAPER_ARCHS["opt-66b"]
    wl = cm.WorkloadSpec(prompt_len=3000, new_tokens=32, microbatch=8)
    one = cm.chunked_prefill_time(cfg, wl.prompt_len, 0, cfg.num_layers, 64)
    for chunk in (512, 128):
        tot = cm.chunked_prefill_time(cfg, wl.prompt_len, chunk,
                                      cfg.num_layers, 64)
        emit(f"chunked_modeled_prefill_overhead_c{chunk}", 0.0,
             f"{tot / one:.3f}x of one-pass")
    base = plan(cfg, wl, 8, paged=True)
    chk = plan(cfg, wl, 8, paged=True, prefill_chunk_tokens=128)
    emit("chunked_modeled_decode_stall_ms_atomic", 0.0,
         f"{base.decode_stall_s * 1e3:.2f}")
    emit("chunked_modeled_decode_stall_ms_c128", 0.0,
         f"{chk.decode_stall_s * 1e3:.2f}")
    emit("chunked_modeled_bubble_frac", 0.0,
         f"{base.bubble_frac:.2f} -> {chk.bubble_frac:.2f}")
    assert chk.decode_stall_s < base.decode_stall_s


def run() -> None:
    measured_study()
    modeled_study()


if __name__ == "__main__":
    run()
